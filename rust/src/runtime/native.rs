//! Native (pure-rust) kernel engine.
//!
//! Mirrors the paper's CPU kernel structure (§9.1): *(1) unpack the input
//! tensors, (2) call a batch matrix multiply, (3) re-pack the result* —
//! except that on this implementation's hot path the "unpack" step no
//! longer moves bytes: kernels consume strided [`TensorView`]s directly.
//! Mapping einsum label orders onto the canonical `[batch, m, k]` /
//! `[batch, k, n]` layout is an O(1) stride permutation, the GEMM packs B
//! straight from the strided tile ([`super::gemm::pack_b_strided`]) and
//! reads A rows through a leading stride, and the generic loop nest and
//! unary reduction index through view strides. A contiguous operand copy
//! is materialized only when a multi-label dim group cannot be collapsed
//! to a single stride — exactly the cases the old code permute-copied
//! unconditionally. EinSums that do not fit the BMM pattern (non-Mul
//! joins, non-Sum aggregations, labels private to one operand) fall back
//! to a generic loop nest over the full iteration space, which implements
//! the extended EinSum semantics exactly.
//!
//! Every path is **bitwise-identical** to the copy-based evaluator it
//! replaced: iteration orders and per-cell accumulation sequences are
//! unchanged, only load addresses differ (`tests/zero_copy.rs`).
//!
//! # Intra-op sharding
//!
//! Every evaluation path accepts a [`ShardScope`] (via
//! [`eval_einsum_scoped`]) and splits itself into independent shards that
//! idle executor workers steal: the BMM path shards across the batch
//! dimension or (for small batches) across GEMM row blocks, the generic
//! loop nest and the unary reduction shard over the leading index-space
//! dimension when it maps to an output label, and pure elementwise maps
//! chunk their buffer. All shard splits are chosen deterministically from
//! the problem shape and write disjoint output regions in the serial
//! kernel's per-cell order, so sharded results are **bitwise-identical**
//! to serial ones for every intra-op degree (`tests/gemm_parallel.rs`).

use super::KernelEngine;
use crate::einsum::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use crate::einsum::label::{project, Label, LabelList};
use crate::error::{Error, Result};
use crate::tensor::{index_space, strides_of, Tensor, TensorView};
use crate::util::{chunk_bounds, serial_scope, BufferPool, ShardScope, SyncPtr, SHARD_MIN};

/// Pure-rust kernel engine. Stateless and cheap to clone.
#[derive(Clone, Debug, Default)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> Self {
        NativeEngine
    }
}

impl KernelEngine for NativeEngine {
    fn eval(&self, op: &EinSum, inputs: &[&Tensor]) -> Result<Tensor> {
        eval_einsum(op, inputs)
    }

    fn eval_scoped(&self, op: &EinSum, inputs: &[&Tensor], scope: &ShardScope) -> Result<Tensor> {
        eval_einsum_scoped(op, inputs, scope)
    }

    fn eval_view(&self, op: &EinSum, inputs: &[&TensorView]) -> Result<Tensor> {
        eval_einsum_view_scoped(op, inputs, &serial_scope())
    }

    fn eval_view_scoped(
        &self,
        op: &EinSum,
        inputs: &[&TensorView],
        scope: &ShardScope,
    ) -> Result<Tensor> {
        eval_einsum_view_scoped(op, inputs, scope)
    }

    fn eval_view_epilogue_scoped(
        &self,
        op: &EinSum,
        inputs: &[&TensorView],
        epilogue: &[crate::einsum::expr::UnaryOp],
        scope: &ShardScope,
    ) -> Result<Tensor> {
        let mut t = eval_einsum_view_scoped(op, inputs, scope)?;
        super::gemm::apply_epilogue(t.data_mut(), epilogue);
        Ok(t)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Evaluate an EinSum on dense tensors (serial).
pub fn eval_einsum(op: &EinSum, inputs: &[&Tensor]) -> Result<Tensor> {
    eval_einsum_scoped(op, inputs, &serial_scope())
}

/// Evaluate an EinSum on dense tensors, sharding the hot loops through
/// `scope`. Owned tensors evaluate as whole-buffer views (an O(1)
/// wrapping), so this shares every code path with
/// [`eval_einsum_view_scoped`].
pub fn eval_einsum_scoped(op: &EinSum, inputs: &[&Tensor], scope: &ShardScope) -> Result<Tensor> {
    let views: Vec<TensorView> = inputs.iter().map(|t| t.view()).collect();
    let refs: Vec<&TensorView> = views.iter().collect();
    eval_einsum_view_scoped(op, &refs, scope)
}

/// Evaluate an EinSum on strided tile views (serial).
pub fn eval_einsum_view(op: &EinSum, inputs: &[&TensorView]) -> Result<Tensor> {
    eval_einsum_view_scoped(op, inputs, &serial_scope())
}

/// Evaluate a whole EinGraph densely, vertex by vertex, with no
/// decomposition — the single-device reference the distributed executor
/// is checked against. Returns the value of **every** vertex keyed by id
/// (inputs included, as cheap `Arc` clones).
pub fn eval_graph(
    g: &crate::einsum::graph::EinGraph,
    inputs: &std::collections::HashMap<crate::einsum::graph::VertexId, Tensor>,
) -> Result<std::collections::HashMap<crate::einsum::graph::VertexId, Tensor>> {
    let mut vals: Vec<Tensor> = Vec::with_capacity(g.len());
    for v in g.vertices() {
        let t = match &v.op {
            EinSum::Input => inputs
                .get(&v.id)
                .cloned()
                .ok_or_else(|| Error::Exec(format!("missing input tensor for {}", v.name)))?,
            op => {
                let ins: Vec<&Tensor> = v.inputs.iter().map(|i| &vals[i.0]).collect();
                eval_einsum(op, &ins)?
            }
        };
        vals.push(t);
    }
    Ok(g.vertices()
        .iter()
        .map(|v| v.id)
        .zip(vals)
        .collect())
}

/// Evaluate an EinSum on strided tile views, sharding the hot loops
/// through `scope` (see the module docs for which paths shard and why the
/// result is bitwise-identical to the serial, copy-based evaluator).
pub fn eval_einsum_view_scoped(
    op: &EinSum,
    inputs: &[&TensorView],
    scope: &ShardScope,
) -> Result<Tensor> {
    match op {
        EinSum::Input => Err(Error::InvalidEinsum(
            "Input vertices are not evaluated".into(),
        )),
        EinSum::Unary { lx, lz, op: u, agg } => {
            if inputs.len() != 1 {
                return Err(Error::InvalidEinsum("unary op needs 1 input".into()));
            }
            eval_unary(lx, lz, *u, *agg, inputs[0], scope)
        }
        EinSum::Binary {
            lx,
            ly,
            lz,
            join,
            agg,
        } => {
            if inputs.len() != 2 {
                return Err(Error::InvalidEinsum("binary op needs 2 inputs".into()));
            }
            eval_binary(lx, ly, lz, *join, *agg, inputs[0], inputs[1], scope)
        }
    }
}

/// Unary: map + optional reduction.
fn eval_unary(
    lx: &LabelList,
    lz: &LabelList,
    u: UnaryOp,
    agg: AggOp,
    x: &TensorView,
    scope: &ShardScope,
) -> Result<Tensor> {
    if x.rank() != lx.len() {
        return Err(Error::Shape(format!(
            "unary: tensor rank {} vs labels {lx:?}",
            x.rank()
        )));
    }
    let bz = project(x.shape(), lz, lx);
    // Fast path: pure map / transpose (no reduction). The permutation is
    // an O(1) stride shuffle; materialization happens once, into the
    // output (and not at all for an identity map of a whole tensor).
    if lz.len() == lx.len() {
        let perm: Vec<usize> = lz
            .iter()
            .map(|l| lx.iter().position(|m| m == l).unwrap())
            .collect();
        let mut t = x.permute(&perm)?.to_tensor();
        if !matches!(u, UnaryOp::Identity) {
            let data = t.data_mut();
            let p = scope.parallelism();
            if p > 1 && data.len() >= SHARD_MIN {
                // Elementwise map: any chunking is bitwise-identical;
                // chunk bounds are still fixed by (len, p) for clarity.
                let len = data.len();
                let ptr = SyncPtr::new(data.as_mut_ptr());
                scope.fork_join(p, |ci| {
                    let (lo, hi) = chunk_bounds(len, p, ci);
                    // SAFETY: [lo, hi) chunks are pairwise disjoint.
                    let s = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(lo), hi - lo) };
                    for v in s {
                        *v = u.apply(*v);
                    }
                });
            } else {
                for v in data {
                    *v = u.apply(*v);
                }
            }
        }
        return Ok(t);
    }
    // Reduction path: iterate I(b_X) in row-major order, reading the
    // input through its view strides, accumulating into the output.
    let mut out = Tensor::full_pooled(&bz, agg.identity());
    let out_strides = strides_of(&bz);
    // position of each lz label within lx
    let zpos: Vec<usize> = lz
        .iter()
        .map(|l| lx.iter().position(|m| m == l).unwrap())
        .collect();
    let xd = x.raw();
    let xs = x.strides().to_vec();
    let p = scope.parallelism();
    // Shard over the leading input dimension when it survives into the
    // output: distinct leading coordinates then touch distinct output
    // cells (disjoint writes), and each cell's accumulation order stays
    // exactly the serial row-major order (bitwise-identical).
    let dim0_in_out = !lx.is_empty() && lz.contains(&lx[0]);
    if p > 1 && dim0_in_out && x.shape()[0] >= 2 && x.len() >= SHARD_MIN {
        let d0 = x.shape()[0];
        let rest: Vec<usize> = x.shape()[1..].to_vec();
        let shards = p.min(d0);
        let optr = SyncPtr::new(out.data_mut().as_mut_ptr());
        scope.fork_join(shards, |s| {
            let (lo, hi) = chunk_bounds(d0, shards, s);
            for i0 in lo..hi {
                for ridx in index_space(&rest) {
                    let mut flat = i0 * xs[0];
                    for (d, &r) in ridx.iter().enumerate() {
                        flat += r * xs[d + 1];
                    }
                    let mut o = 0usize;
                    for (st, &pz) in out_strides.iter().zip(&zpos) {
                        o += st * if pz == 0 { i0 } else { ridx[pz - 1] };
                    }
                    // SAFETY: o depends injectively on i0 for fixed ridx
                    // (lx[0] is an output coordinate), so shards write
                    // disjoint cells.
                    unsafe {
                        let cell = optr.get().add(o);
                        *cell = agg.combine(*cell, u.apply(xd[flat]));
                    }
                }
            }
        });
        return Ok(out);
    }
    let out_data = out.data_mut();
    for idx in index_space(x.shape()) {
        let mut flat = 0usize;
        for (d, &i) in idx.iter().enumerate() {
            flat += i * xs[d];
        }
        let mut o = 0usize;
        for (s, &pz) in out_strides.iter().zip(&zpos) {
            o += s * idx[pz];
        }
        out_data[o] = agg.combine(out_data[o], u.apply(xd[flat]));
    }
    Ok(out)
}

/// Label classification for the BMM fast path.
struct BmmPlan {
    batch: LabelList,
    m: LabelList,
    n: LabelList,
    k: LabelList,
}

/// Classify labels as batch (X,Y,Z), m (X,Z), n (Y,Z), k (X,Y). Returns
/// `None` if any label falls outside those classes (e.g. appears in only
/// one operand), which the generic path handles.
fn bmm_plan(lx: &LabelList, ly: &LabelList, lz: &LabelList) -> Option<BmmPlan> {
    let mut plan = BmmPlan {
        batch: vec![],
        m: vec![],
        n: vec![],
        k: vec![],
    };
    let in_x = |l: &Label| lx.contains(l);
    let in_y = |l: &Label| ly.contains(l);
    let in_z = |l: &Label| lz.contains(l);
    let mut seen: Vec<Label> = vec![];
    for l in lx.iter().chain(ly.iter()) {
        if seen.contains(l) {
            continue;
        }
        seen.push(*l);
        match (in_x(l), in_y(l), in_z(l)) {
            (true, true, true) => plan.batch.push(*l),
            (true, false, true) => plan.m.push(*l),
            (false, true, true) => plan.n.push(*l),
            (true, true, false) => plan.k.push(*l),
            _ => return None,
        }
    }
    Some(plan)
}

/// Binary EinSum evaluation.
#[allow(clippy::too_many_arguments)]
fn eval_binary(
    lx: &LabelList,
    ly: &LabelList,
    lz: &LabelList,
    join: JoinOp,
    agg: AggOp,
    x: &TensorView,
    y: &TensorView,
    scope: &ShardScope,
) -> Result<Tensor> {
    if x.rank() != lx.len() || y.rank() != ly.len() {
        return Err(Error::Shape(format!(
            "binary: ranks {}/{} vs labels {lx:?}/{ly:?}",
            x.rank(),
            y.rank()
        )));
    }
    // shared labels must agree on size
    for (i, l) in lx.iter().enumerate() {
        if let Some(j) = ly.iter().position(|m| m == l) {
            if x.shape()[i] != y.shape()[j] {
                return Err(Error::Shape(format!(
                    "label {l}: {} vs {}",
                    x.shape()[i],
                    y.shape()[j]
                )));
            }
        }
    }
    // GEMM fast path: Mul/Sum with a clean batch/m/n/k split.
    if join == JoinOp::Mul && agg == AggOp::Sum {
        if let Some(plan) = bmm_plan(lx, ly, lz) {
            return eval_bmm(&plan, lx, ly, lz, x, y, scope);
        }
    }
    eval_binary_generic_scoped(lx, ly, lz, join, agg, x, y, scope)
}

/// Collapse a run of view dims into the stride of the flattened group,
/// when the layout allows it: ignoring size-1 dims, each kept stride must
/// chain (`stride[i] == shape[i+1] * stride[i+1]`). An empty (or all
/// size-1) group collapses to stride 0, which callers never advance.
fn collapse_dims(shape: &[usize], strides: &[usize]) -> Option<usize> {
    let kept: Vec<(usize, usize)> = shape
        .iter()
        .zip(strides)
        .filter(|(&d, _)| d != 1)
        .map(|(&d, &s)| (d, s))
        .collect();
    if kept.is_empty() {
        return Some(0);
    }
    for w in kept.windows(2) {
        let ((_, s1), (d2, s2)) = (w[0], w[1]);
        if s1 != d2 * s2 {
            return None;
        }
    }
    kept.last().map(|&(_, s)| s)
}

/// Flat offset of every batch entry (row-major over the batch dims).
fn batch_offsets(dims: &[usize], strides: &[usize]) -> Vec<usize> {
    index_space(dims)
        .map(|key| key.iter().zip(strides).map(|(&i, &s)| i * s).sum())
        .collect()
}

/// Strided-BMM path: map X onto `[B, M, K]` and Y onto `[B, K, N]` by
/// stride permutation (O(1)), run the packed GEMM per batch entry reading
/// A through a row stride and packing B straight from the strided tile,
/// then permute the `[B, M, N]` result to `l_Z` order (O(1) when the
/// order already matches, the common 2-D case). An operand materializes a
/// contiguous copy only when its m/k (resp. k/n) label groups do not
/// collapse to single strides — the layouts the old code permute-copied
/// for every call.
///
/// Intra-op sharding: a batch dimension at least as wide as the scope's
/// fan-out shards across batch entries (disjoint `[b, m, n]` slabs,
/// serial kernel per slab); smaller batches run
/// [`super::gemm::sgemm_packed_scoped`] per entry, sharding GEMM row
/// blocks instead. Both splits are bitwise-identical to the serial loop
/// because the per-entry kernel is.
fn eval_bmm(
    plan: &BmmPlan,
    lx: &LabelList,
    ly: &LabelList,
    lz: &LabelList,
    x: &TensorView,
    y: &TensorView,
    scope: &ShardScope,
) -> Result<Tensor> {
    let dim_of_x = |l: &Label| x.shape()[lx.iter().position(|m| m == l).unwrap()];
    let dim_of_y = |l: &Label| y.shape()[ly.iter().position(|m| m == l).unwrap()];
    let b: usize = plan.batch.iter().map(dim_of_x).product();
    let m: usize = plan.m.iter().map(dim_of_x).product();
    let k: usize = plan.k.iter().map(dim_of_x).product();
    let n: usize = plan.n.iter().map(dim_of_y).product();

    // canonical label orders
    let x_order: LabelList = plan
        .batch
        .iter()
        .chain(plan.m.iter())
        .chain(plan.k.iter())
        .copied()
        .collect();
    let y_order: LabelList = plan
        .batch
        .iter()
        .chain(plan.k.iter())
        .chain(plan.n.iter())
        .copied()
        .collect();
    let perm_x: Vec<usize> = x_order
        .iter()
        .map(|l| lx.iter().position(|m2| m2 == l).unwrap())
        .collect();
    let perm_y: Vec<usize> = y_order
        .iter()
        .map(|l| ly.iter().position(|m2| m2 == l).unwrap())
        .collect();
    let xv = x.permute(&perm_x)?; // logical [B.., M.., K..], strided
    let yv = y.permute(&perm_y)?; // logical [B.., K.., N..], strided
    let nb_ = plan.batch.len();
    let nm = plan.m.len();
    let nk = plan.k.len();

    // A in place when the M group collapses to a row stride and the K
    // group collapses to unit stride (contiguous K runs for the
    // micro-kernel); otherwise materialize the canonical copy once.
    let sm = collapse_dims(&xv.shape()[nb_..nb_ + nm], &xv.strides()[nb_..nb_ + nm]);
    let sk = collapse_dims(&xv.shape()[nb_ + nm..], &xv.strides()[nb_ + nm..]);
    let a_direct: Option<usize> = match (sm, sk) {
        (Some(sm), Some(sk)) if k <= 1 || sk == 1 => Some(if m <= 1 { k } else { sm }),
        _ => None,
    };
    let a_mat: Option<Tensor> = if a_direct.is_some() {
        None
    } else {
        Some(xv.to_tensor())
    };
    let (a_data, lda, a_offs): (&[f32], usize, Vec<usize>) = match (&a_mat, a_direct) {
        (Some(t), _) => (t.data(), k, (0..b).map(|bi| bi * m * k).collect()),
        (None, Some(lda)) => (
            xv.raw(),
            lda,
            batch_offsets(&xv.shape()[..nb_], &xv.strides()[..nb_]),
        ),
        (None, None) => unreachable!(),
    };
    // B packs from any (row, col) stride pair, so it needs only the two
    // group collapses — no unit-stride requirement.
    let rk = collapse_dims(&yv.shape()[nb_..nb_ + nk], &yv.strides()[nb_..nb_ + nk]);
    let rn = collapse_dims(&yv.shape()[nb_ + nk..], &yv.strides()[nb_ + nk..]);
    let b_direct: Option<(usize, usize)> = match (rk, rn) {
        (Some(r), Some(c)) => Some((r, c)),
        _ => None,
    };
    let b_mat: Option<Tensor> = if b_direct.is_some() {
        None
    } else {
        Some(yv.to_tensor())
    };
    let (b_data, rsb, csb, b_offs): (&[f32], usize, usize, Vec<usize>) = match (&b_mat, b_direct) {
        (Some(t), _) => (t.data(), n, 1, (0..b).map(|bi| bi * k * n).collect()),
        (None, Some((rsb, csb))) => (
            yv.raw(),
            rsb,
            csb,
            batch_offsets(&yv.shape()[..nb_], &yv.strides()[..nb_]),
        ),
        (None, None) => unreachable!(),
    };

    let mut out = BufferPool::take(b * m * n);
    let p = scope.parallelism();
    if p > 1 && b >= p && b * m * k * n >= SHARD_MIN {
        // Wide batch: at most p shards, each a contiguous batch range
        // running the serial GEMM per entry (bounded fork-join overhead,
        // matching every other sharded path's p-way split). Pack buffers
        // come from each helper thread's own pool.
        let optr = SyncPtr::new(out.as_mut_ptr());
        scope.fork_join(p, |s| {
            let (blo, bhi) = chunk_bounds(b, p, s);
            let base = optr.get();
            for bi in blo..bhi {
                let a = &a_data[a_offs[bi]..];
                let bp = super::gemm::pack_b_strided(k, n, &b_data[b_offs[bi]..], rsb, csb);
                // SAFETY: batch slabs [bi*m*n, (bi+1)*m*n) are disjoint
                // across the disjoint batch ranges.
                let oo = unsafe { std::slice::from_raw_parts_mut(base.add(bi * m * n), m * n) };
                oo.fill(0.0); // beta = 0 prologue (pooled buffers are stale)
                super::gemm::sgemm_rows(0, m, k, n, 1.0, a, lda, &bp, oo);
            }
        });
    } else {
        // Narrow batch (typically b == 1 after decomposition): shard the
        // GEMM's M row blocks instead.
        for bi in 0..b {
            let a = &a_data[a_offs[bi]..];
            let bp = super::gemm::pack_b_strided(k, n, &b_data[b_offs[bi]..], rsb, csb);
            let oo = &mut out[bi * m * n..(bi + 1) * m * n];
            super::gemm::sgemm_packed_scoped(m, k, n, 1.0, a, lda, &bp, 0.0, oo, scope);
        }
    }
    // canonical output label order: [batch, m, n]
    let z_canon: LabelList = plan
        .batch
        .iter()
        .chain(plan.m.iter())
        .chain(plan.n.iter())
        .copied()
        .collect();
    let z_shape_canon: Vec<usize> = plan
        .batch
        .iter()
        .map(dim_of_x)
        .chain(plan.m.iter().map(dim_of_x))
        .chain(plan.n.iter().map(dim_of_y))
        .collect();
    let t = Tensor::new(z_shape_canon, out)?;
    // permute canonical -> requested lz order (O(1) when identical)
    let perm_z: Vec<usize> = lz
        .iter()
        .map(|l| z_canon.iter().position(|m2| m2 == l).unwrap())
        .collect();
    t.permute(&perm_z)
}

/// Generic loop nest: iterate the joint index space of all unique labels,
/// apply the join scalar function, aggregate into the output cell. Exact
/// for every `(+)`/`(x)` pair, including broadcast joins where one operand
/// indexes a subset of the labels. Serial oracle for the BMM fast path —
/// production callers go through the scoped form below.
#[cfg(test)]
fn eval_binary_generic(
    lx: &LabelList,
    ly: &LabelList,
    lz: &LabelList,
    join: JoinOp,
    agg: AggOp,
    x: &Tensor,
    y: &Tensor,
) -> Result<Tensor> {
    eval_binary_generic_scoped(lx, ly, lz, join, agg, &x.view(), &y.view(), &serial_scope())
}

/// [`eval_binary_generic`] with view inputs and intra-op sharding: the
/// nest walks per-label *view* strides, so strided tiles evaluate in
/// place. When the *leading* unique label maps to an output coordinate,
/// the iteration splits over that label's range. Each shard then writes a
/// disjoint set of output cells, and every cell still receives its
/// contributions in the serial row-major order (its leading coordinate is
/// fixed), so the result is bitwise-identical to the serial nest. A
/// leading label that is reduced away (no disjoint split exists along it)
/// falls back to serial.
#[allow(clippy::too_many_arguments)]
fn eval_binary_generic_scoped(
    lx: &LabelList,
    ly: &LabelList,
    lz: &LabelList,
    join: JoinOp,
    agg: AggOp,
    x: &TensorView,
    y: &TensorView,
    scope: &ShardScope,
) -> Result<Tensor> {
    let uniq = crate::einsum::label::concat_dedup(lx, ly);
    // bound of each unique label
    let ubound: Vec<usize> = uniq
        .iter()
        .map(|l| {
            lx.iter()
                .position(|m| m == l)
                .map(|i| x.shape()[i])
                .unwrap_or_else(|| y.shape()[ly.iter().position(|m| m == l).unwrap()])
        })
        .collect();
    let bz = project(&ubound, lz, &uniq);
    let mut out = Tensor::full_pooled(&bz, agg.identity());

    // Strides of x/y/out with respect to the joint index (per unique
    // label). x/y use their *view* strides; out is owned row-major.
    let xs = x.strides().to_vec();
    let ys = y.strides().to_vec();
    let zs = strides_of(&bz);
    let stride_for = |labels_of: &LabelList, strides: &[usize], l: &Label| -> usize {
        labels_of
            .iter()
            .position(|m| m == l)
            .map(|i| strides[i])
            .unwrap_or(0)
    };
    let jx: Vec<usize> = uniq.iter().map(|l| stride_for(lx, &xs, l)).collect();
    let jy: Vec<usize> = uniq.iter().map(|l| stride_for(ly, &ys, l)).collect();
    let jz: Vec<usize> = uniq.iter().map(|l| stride_for(lz, &zs, l)).collect();

    let xd = x.raw();
    let yd = y.raw();
    let rank = uniq.len();
    if ubound.iter().any(|&b| b == 0) {
        return Ok(out);
    }
    if rank == 0 {
        let od = out.data_mut();
        od[0] = agg.combine(od[0], join.apply(xd[0], yd[0]));
        return Ok(out);
    }
    let total: usize = ubound.iter().product();
    let p = scope.parallelism();
    // Output strides are never 0, so jz[0] != 0 iff uniq[0] is in l_Z.
    let od = SyncPtr::new(out.data_mut().as_mut_ptr());
    if p > 1 && jz[0] != 0 && ubound[0] >= 2 && total >= SHARD_MIN {
        let shards = p.min(ubound[0]);
        scope.fork_join(shards, |s| {
            let (lo, hi) = chunk_bounds(ubound[0], shards, s);
            // SAFETY: uniq[0] is an output coordinate, so disjoint
            // leading ranges write disjoint output cells.
            unsafe { generic_nest(lo, hi, &ubound, &jx, &jy, &jz, xd, yd, od.get(), join, agg) };
        });
    } else {
        let hi = ubound[0];
        // SAFETY: single caller, exclusive access to the output buffer.
        unsafe { generic_nest(0, hi, &ubound, &jx, &jy, &jz, xd, yd, od.get(), join, agg) };
    }
    Ok(out)
}

/// Odometer over the joint index space with the leading dimension
/// restricted to `[lo, hi)`, maintaining the three flat offsets
/// incrementally.
///
/// # Safety
///
/// `od` must be valid for the whole output buffer, and concurrent callers
/// must use disjoint `[lo, hi)` ranges whose cells do not overlap (which
/// holds exactly when `jz[0] != 0`, i.e. the leading unique label is an
/// output coordinate).
#[allow(clippy::too_many_arguments)]
unsafe fn generic_nest(
    lo: usize,
    hi: usize,
    ubound: &[usize],
    jx: &[usize],
    jy: &[usize],
    jz: &[usize],
    xd: &[f32],
    yd: &[f32],
    od: *mut f32,
    join: JoinOp,
    agg: AggOp,
) {
    if lo >= hi {
        return;
    }
    let rank = ubound.len();
    let mut idx = vec![0usize; rank];
    idx[0] = lo;
    let (mut ox, mut oy, mut oz) = (lo * jx[0], lo * jy[0], lo * jz[0]);
    loop {
        *od.add(oz) = agg.combine(*od.add(oz), join.apply(xd[ox], yd[oy]));
        // increment
        let mut d = rank;
        loop {
            if d == 0 {
                return;
            }
            d -= 1;
            idx[d] += 1;
            ox += jx[d];
            oy += jy[d];
            oz += jz[d];
            let bound = if d == 0 { hi } else { ubound[d] };
            if idx[d] < bound {
                break;
            }
            if d == 0 {
                return;
            }
            // reset dimension d
            ox -= jx[d] * ubound[d];
            oy -= jy[d] * ubound[d];
            oz -= jz[d] * ubound[d];
            idx[d] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::label::labels;

    fn l(s: &str) -> LabelList {
        labels(s)
    }

    #[test]
    fn matmul_matches_manual() {
        let x = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let y = Tensor::new(vec![2, 2], vec![5., 6., 7., 8.]).unwrap();
        let op = EinSum::contraction(l("i j"), l("j k"), l("i k"));
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        assert_eq!(z.data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_transposed_output() {
        let x = Tensor::random(&[3, 4], 1);
        let y = Tensor::random(&[4, 5], 2);
        let zik = eval_einsum(
            &EinSum::contraction(l("i j"), l("j k"), l("i k")),
            &[&x, &y],
        )
        .unwrap();
        let zki = eval_einsum(
            &EinSum::contraction(l("i j"), l("j k"), l("k i")),
            &[&x, &y],
        )
        .unwrap();
        assert_eq!(zki.shape(), &[5, 3]);
        assert!(zki.permute(&[1, 0]).unwrap().allclose(&zik, 1e-5, 1e-6));
    }

    #[test]
    fn batch_matmul_sum_out_batch() {
        // Paper example: Z_ik <- sum_{b,j} X_ijb Y_jbk
        let x = Tensor::random(&[3, 4, 2], 1);
        let y = Tensor::random(&[4, 2, 5], 2);
        let op = EinSum::contraction(l("i j b"), l("j b k"), l("i k"));
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        assert_eq!(z.shape(), &[3, 5]);
        // manual check at one cell
        let mut want = 0.0;
        for j in 0..4 {
            for b in 0..2 {
                want += x.at(&[1, j, b]) * y.at(&[j, b, 3]);
            }
        }
        assert!((z.at(&[1, 3]) - want).abs() < 1e-4);
    }

    #[test]
    fn generic_vs_bmm_agree() {
        // Force the generic path by wrapping Mul/Sum in a contraction the
        // planner *can* BMM, then compare against the generic evaluator
        // called directly.
        let x = Tensor::random(&[4, 6], 3);
        let y = Tensor::random(&[6, 3], 4);
        let generic =
            eval_binary_generic(&l("i j"), &l("j k"), &l("i k"), JoinOp::Mul, AggOp::Sum, &x, &y)
                .unwrap();
        let fast = eval_einsum(
            &EinSum::contraction(l("i j"), l("j k"), l("i k")),
            &[&x, &y],
        )
        .unwrap();
        assert!(generic.allclose(&fast, 1e-5, 1e-6));
    }

    #[test]
    fn view_tiles_evaluate_bitwise_equal_to_owned_tiles() {
        // The zero-copy contract: a strided tile view must produce the
        // exact bytes the materialized tile produces, on both the BMM and
        // generic paths.
        let x = Tensor::random(&[9, 11], 5);
        let y = Tensor::random(&[11, 7], 6);
        let xv = x.slice_view(&[2, 3], &[4, 5]).unwrap();
        let yv = y.slice_view(&[3, 1], &[5, 4]).unwrap();
        let xo = x.slice(&[2, 3], &[4, 5]).unwrap();
        let yo = y.slice(&[3, 1], &[5, 4]).unwrap();
        let bmm = EinSum::contraction(l("i j"), l("j k"), l("i k"));
        let via_views = eval_einsum_view(&bmm, &[&xv, &yv]).unwrap();
        let via_owned = eval_einsum(&bmm, &[&xo, &yo]).unwrap();
        assert_eq!(via_views, via_owned);
        let generic = EinSum::Binary {
            lx: l("i j"),
            ly: l("j k"),
            lz: l("i k"),
            join: JoinOp::SquaredDiff,
            agg: AggOp::Sum,
        };
        let gv = eval_einsum_view(&generic, &[&xv, &yv]).unwrap();
        let go = eval_einsum(&generic, &[&xo, &yo]).unwrap();
        assert_eq!(gv, go);
    }

    #[test]
    fn transposed_operand_layout_falls_back_and_matches() {
        // lx = (j, i): the m label has unit stride and the k label has
        // row stride, so A cannot stream contiguous K runs — the path
        // must materialize and still match the owned evaluation bitwise.
        let x = Tensor::random(&[6, 5], 7); // labels (j, i)
        let y = Tensor::random(&[6, 4], 8); // labels (j, k)
        let op = EinSum::contraction(l("j i"), l("j k"), l("i k"));
        let via_views = eval_einsum_view(&op, &[&x.view(), &y.view()]).unwrap();
        let via_owned = eval_einsum(&op, &[&x, &y]).unwrap();
        assert_eq!(via_views, via_owned);
        // sanity vs the generic nest
        let gen =
            eval_binary_generic(&l("j i"), &l("j k"), &l("i k"), JoinOp::Mul, AggOp::Sum, &x, &y)
                .unwrap();
        assert!(via_owned.allclose(&gen, 1e-5, 1e-6));
    }

    #[test]
    fn collapse_dims_rules() {
        // contiguous pair collapses to the inner stride
        assert_eq!(collapse_dims(&[3, 4], &[4, 1]), Some(1));
        // chained but non-unit inner stride
        assert_eq!(collapse_dims(&[3, 4], &[8, 2]), Some(2));
        // broken chain (a sliced dim): no collapse
        assert_eq!(collapse_dims(&[3, 4], &[16, 1]), None);
        // size-1 dims are transparent
        assert_eq!(collapse_dims(&[1, 4], &[999, 1]), Some(1));
        assert_eq!(collapse_dims(&[], &[]), Some(0));
        assert_eq!(collapse_dims(&[1, 1], &[5, 9]), Some(0));
    }

    #[test]
    fn l2_distance_einsum() {
        // Z_ik <- sum_j (X_ij - Y_jk)^2 — paper's squared-L2 example.
        let x = Tensor::random(&[3, 4], 5);
        let y = Tensor::random(&[4, 2], 6);
        let op = EinSum::Binary {
            lx: l("i j"),
            ly: l("j k"),
            lz: l("i k"),
            join: JoinOp::SquaredDiff,
            agg: AggOp::Sum,
        };
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        let mut want = 0.0;
        for j in 0..4 {
            let d = x.at(&[2, j]) - y.at(&[j, 1]);
            want += d * d;
        }
        assert!((z.at(&[2, 1]) - want).abs() < 1e-4);
    }

    #[test]
    fn linf_distance_einsum() {
        // Z_ik <- max_j |X_ij - Y_jk| — paper's L-inf example.
        let x = Tensor::random(&[3, 4], 7);
        let y = Tensor::random(&[4, 2], 8);
        let op = EinSum::Binary {
            lx: l("i j"),
            ly: l("j k"),
            lz: l("i k"),
            join: JoinOp::AbsDiff,
            agg: AggOp::Max,
        };
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        let want = (0..4)
            .map(|j| (x.at(&[0, j]) - y.at(&[j, 0])).abs())
            .fold(f32::NEG_INFINITY, f32::max);
        assert!((z.at(&[0, 0]) - want).abs() < 1e-5);
    }

    #[test]
    fn broadcast_join_divide() {
        // Y_ij <- E_ij / S_i
        let e = Tensor::random(&[3, 4], 9);
        let s = Tensor::full(&[3], 2.0);
        let op = EinSum::Binary {
            lx: l("i j"),
            ly: l("i"),
            lz: l("i j"),
            join: JoinOp::Div,
            agg: AggOp::Sum,
        };
        let z = eval_einsum(&op, &[&e, &s]).unwrap();
        assert!((z.at(&[1, 2]) - e.at(&[1, 2]) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn unary_map_and_reduce() {
        let x = Tensor::new(vec![2, 3], vec![1., -2., 3., -4., 5., -6.]).unwrap();
        let relu = eval_einsum(&EinSum::map(l("i j"), UnaryOp::Relu), &[&x]).unwrap();
        assert_eq!(relu.data(), &[1., 0., 3., 0., 5., 0.]);
        let rowmax = eval_einsum(&EinSum::reduce(l("i j"), l("i"), AggOp::Max), &[&x]).unwrap();
        assert_eq!(rowmax.data(), &[3., 5.]);
        let colsum = eval_einsum(&EinSum::reduce(l("i j"), l("j"), AggOp::Sum), &[&x]).unwrap();
        assert_eq!(colsum.data(), &[-3., 3., -3.]);
    }

    #[test]
    fn unary_on_view_tiles_matches_owned() {
        let x = Tensor::random(&[8, 10], 11);
        let xv = x.slice_view(&[1, 2], &[5, 6]).unwrap();
        let xo = x.slice(&[1, 2], &[5, 6]).unwrap();
        for op in [
            EinSum::map(l("i j"), UnaryOp::Exp),
            EinSum::reduce(l("i j"), l("i"), AggOp::Sum),
            EinSum::reduce(l("i j"), l("j"), AggOp::Max),
            EinSum::Unary {
                lx: l("i j"),
                lz: l("j i"),
                op: UnaryOp::Scale(0.5),
                agg: AggOp::Sum,
            },
        ] {
            let v = eval_einsum_view(&op, &[&xv]).unwrap();
            let o = eval_einsum(&op, &[&xo]).unwrap();
            assert_eq!(v, o, "{op:?}");
        }
    }

    #[test]
    fn unary_transpose_with_map() {
        let x = Tensor::random(&[2, 3, 4], 10);
        let op = EinSum::Unary {
            lx: l("a b c"),
            lz: l("c a b"),
            op: UnaryOp::Scale(2.0),
            agg: AggOp::Sum,
        };
        let z = eval_einsum(&op, &[&x]).unwrap();
        assert_eq!(z.shape(), &[4, 2, 3]);
        assert!((z.at(&[3, 1, 0]) - 2.0 * x.at(&[1, 0, 3])).abs() < 1e-6);
    }

    #[test]
    fn x_only_label_reduced() {
        // Z_k <- sum_{i,j} X_ij * Y_jk — i appears only in X, not in Z:
        // falls off the BMM plan, exercised via the generic path.
        let x = Tensor::random(&[3, 4], 11);
        let y = Tensor::random(&[4, 2], 12);
        let op = EinSum::contraction(l("i j"), l("j k"), l("k"));
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        let mut want = 0.0;
        for i in 0..3 {
            for j in 0..4 {
                want += x.at(&[i, j]) * y.at(&[j, 1]);
            }
        }
        assert!((z.at(&[1]) - want).abs() < 1e-4);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let x = Tensor::zeros(&[3, 4]);
        let y = Tensor::zeros(&[5, 2]);
        let op = EinSum::contraction(l("i j"), l("j k"), l("i k"));
        assert!(eval_einsum(&op, &[&x, &y]).is_err());
    }

    #[test]
    fn rank1_dot_product() {
        let x = Tensor::new(vec![3], vec![1., 2., 3.]).unwrap();
        let y = Tensor::new(vec![3], vec![4., 5., 6.]).unwrap();
        let op = EinSum::contraction(l("i"), l("i"), vec![]);
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        assert_eq!(z.rank(), 0);
        assert_eq!(z.at(&[]), 32.0);
    }
}

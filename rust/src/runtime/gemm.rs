//! In-tree single-precision GEMM (row-major), replacing the unavailable
//! `matrixmultiply` crate.
//!
//! The kernel is an axpy-panel formulation: for each row of A, stream the
//! matching rows of B and accumulate into the C row. The inner loop is a
//! contiguous fused multiply-add over `n`, which LLVM auto-vectorizes.
//! Rows of A are processed in blocks of 4 so each loaded B row is reused
//! 4x from registers/L1 — the main lever found during the §Perf pass.

/// `C = alpha * A @ B + beta * C`, all row-major:
/// `a`: m x k, `b`: k x n, `c`: m x n.
pub fn sgemm(m: usize, k: usize, n: usize, alpha: f32, a: &[f32], b: &[f32], beta: f32, c: &mut [f32]) {
    debug_assert!(a.len() >= m * k);
    debug_assert!(b.len() >= k * n);
    debug_assert!(c.len() >= m * n);
    // prologue: scale C by beta
    if beta == 0.0 {
        c[..m * n].fill(0.0);
    } else if beta != 1.0 {
        for v in &mut c[..m * n] {
            *v *= beta;
        }
    }
    if alpha == 0.0 || m == 0 || n == 0 || k == 0 {
        return;
    }
    // K-panel blocking: keep a KB x n panel of B hot in L2 across all rows
    // of A (the §Perf pass's second lever — without it the B matrix falls
    // out of cache for k >~ 512 and throughput drops ~25%).
    const KB: usize = 256;
    let mut k0 = 0;
    while k0 < k {
        let kb = KB.min(k - k0);
        let mut i = 0;
        // 4-row blocks: each loaded B row is reused 4x from registers
        while i + 4 <= m {
            let (a0, a1, a2, a3) = (
                &a[i * k + k0..i * k + k0 + kb],
                &a[(i + 1) * k + k0..(i + 1) * k + k0 + kb],
                &a[(i + 2) * k + k0..(i + 2) * k + k0 + kb],
                &a[(i + 3) * k + k0..(i + 3) * k + k0 + kb],
            );
            // split the 4 output rows without aliasing
            let (c01, c23) = c[i * n..(i + 4) * n].split_at_mut(2 * n);
            let (c0, c1) = c01.split_at_mut(n);
            let (c2, c3) = c23.split_at_mut(n);
            for kk in 0..kb {
                let brow = &b[(k0 + kk) * n..(k0 + kk) * n + n];
                let f0 = alpha * a0[kk];
                let f1 = alpha * a1[kk];
                let f2 = alpha * a2[kk];
                let f3 = alpha * a3[kk];
                for j in 0..n {
                    let bv = brow[j];
                    c0[j] += f0 * bv;
                    c1[j] += f1 * bv;
                    c2[j] += f2 * bv;
                    c3[j] += f3 * bv;
                }
            }
            i += 4;
        }
        // remainder rows
        while i < m {
            let arow = &a[i * k + k0..i * k + k0 + kb];
            let crow = &mut c[i * n..i * n + n];
            for (kk, &av) in arow.iter().enumerate() {
                let f = alpha * av;
                if f != 0.0 {
                    let brow = &b[(k0 + kk) * n..(k0 + kk) * n + n];
                    for j in 0..n {
                        crow[j] += f * brow[j];
                    }
                }
            }
            i += 1;
        }
        k0 += kb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from_u64(seed);
        (0..n).map(|_| r.next_centered()).collect()
    }

    #[test]
    fn matches_naive_various_shapes() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (4, 4, 4), (8, 3, 9), (17, 13, 11), (5, 64, 2)] {
            let a = rand_vec(m * k, 1);
            let b = rand_vec(k * n, 2);
            let want = naive(m, k, n, &a, &b);
            let mut c = vec![0.0f32; m * n];
            sgemm(m, k, n, 1.0, &a, &b, 0.0, &mut c);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4, "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn alpha_beta_semantics() {
        let (m, k, n) = (4, 3, 5);
        let a = rand_vec(m * k, 3);
        let b = rand_vec(k * n, 4);
        let c0 = rand_vec(m * n, 5);
        let mut c = c0.clone();
        sgemm(m, k, n, 2.0, &a, &b, 0.5, &mut c);
        let ab = naive(m, k, n, &a, &b);
        for i in 0..m * n {
            let want = 2.0 * ab[i] + 0.5 * c0[i];
            assert!((c[i] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn zero_dims_are_noops() {
        let mut c = vec![1.0f32; 0];
        sgemm(0, 3, 0, 1.0, &[], &[], 0.0, &mut c);
    }
}

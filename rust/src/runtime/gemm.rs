//! In-tree single-precision GEMM (row-major), replacing the unavailable
//! `matrixmultiply` crate.
//!
//! # Structure
//!
//! A packed micro-kernel formulation in the BLIS mold, sized for the
//! intra-op sharding the executor layers on top:
//!
//! * **Packing** ([`pack_b`]): B is repacked *once per call* into
//!   `KB x NB` panels ([`PackedB`]) so the micro-kernel streams
//!   contiguous L1-resident strips — and so every M row-block reuses the
//!   same packed bytes, whichever thread runs it.
//! * **Micro-kernel**: an [`MR`]` x NR` register tile accumulated over
//!   a K panel, written back to C as `c += alpha * acc` once per panel.
//!   The fixed-size accumulator arrays auto-vectorize.
//! * **Sharding** ([`row_shards`] / [`sgemm_scoped`]): the M loop splits
//!   into contiguous, [`MR`]-aligned row ranges that are independent —
//!   each writes a disjoint slab of C from the shared [`PackedB`]. Shard
//!   boundaries depend only on `(m, shard count)`, and each C row sees
//!   the *same* update sequence (K panels ascending, N panels ascending)
//!   no matter which shard runs it, so the sharded kernel is
//!   **bitwise-identical** to the serial one (`tests/gemm_parallel.rs`
//!   locks this in across shard counts and runs).
//!
//! # The `alpha`/`beta` contract
//!
//! [`sgemm`] computes `C = alpha * (A @ B) + beta * C` with BLAS edge
//! semantics: `beta == 0` *overwrites* C (existing contents — including
//! NaN/Inf — are ignored, not multiplied); `beta == 1` leaves C as the
//! accumulator; `alpha == 0` only applies the beta scaling. The product
//! term accumulates in f32 (no widening), grouped per K panel.

use crate::util::{PooledVec, ShardScope, SyncPtr, SHARD_MIN};

/// Micro-kernel row block: shard boundaries are multiples of this.
pub const MR: usize = 4;
/// Micro-kernel column strip width (stays in registers).
const NR: usize = 16;
/// K-panel depth: a `KB x NB` packed panel is reused by every row block.
const KB: usize = 256;
/// N-panel width of the packed B layout (multiple of [`NR`]).
const NB: usize = 256;

/// Storage behind a [`PackedB`]: borrowed when the input layout already
/// *is* the packed layout, otherwise a pooled scratch buffer that returns
/// to the thread's [`crate::util::BufferPool`] on drop.
enum PackData<'a> {
    Borrowed(&'a [f32]),
    Owned(PooledVec),
}

/// B packed into `KB x NB` panels, row-major within each panel.
///
/// Layout: panels ordered K-panel-major then N-panel; the panel covering
/// `k in [k0, k0+kb) x j in [j0, j0+nb)` starts at offset
/// `k0 * n + kb * j0` and is `kb * nb` contiguous floats. Packing cost is
/// one pass over B; every row block of A then reads B only through these
/// cache-friendly strips. When the input is contiguous row-major with
/// `n <= NB` the packed layout coincides with it byte-for-byte, so B is
/// *borrowed* rather than copied — the common case for small
/// post-decomposition tiles. Owned pack buffers are pooled scratch.
pub struct PackedB<'a> {
    data: PackData<'a>,
    k: usize,
    n: usize,
}

/// Pack row-major `b` (`k x n`, contiguous) for [`sgemm_rows`].
pub fn pack_b(k: usize, n: usize, b: &[f32]) -> PackedB<'_> {
    assert!(
        b.len() >= k * n,
        "pack_b: B has {} elements, need k*n = {}",
        b.len(),
        k * n
    );
    pack_b_strided(k, n, b, n, 1)
}

/// Pack a *strided* `k x n` operand: element `(kk, j)` lives at
/// `b[kk * rsb + j * csb]`. This is how the BMM path packs straight from
/// a [`crate::tensor::TensorView`] tile — no contiguous materialization
/// of B ever happens. The packed bytes are a pure relayout — no
/// arithmetic — so neither packing order nor source layout can affect
/// results: panels are value-identical to packing a materialized copy.
pub fn pack_b_strided(k: usize, n: usize, b: &[f32], rsb: usize, csb: usize) -> PackedB<'_> {
    if k > 0 && n > 0 {
        let max = (k - 1) * rsb + (n - 1) * csb;
        assert!(
            b.len() > max,
            "pack_b_strided: B has {} elements, max index {max}",
            b.len()
        );
    }
    if csb == 1 && rsb == n && n <= NB {
        // Single N-panel over a contiguous row-major input: for every K
        // panel, base = k0 * n and nb = n, so the packed layout is
        // exactly the input. Borrow it.
        return PackedB {
            data: PackData::Borrowed(&b[..k * n]),
            k,
            n,
        };
    }
    let mut data = PooledVec::take(k * n);
    let mut k0 = 0;
    while k0 < k {
        let kb = KB.min(k - k0);
        let mut j0 = 0;
        while j0 < n {
            let nb = NB.min(n - j0);
            let base = k0 * n + kb * j0;
            for kk in 0..kb {
                let src = (k0 + kk) * rsb + j0 * csb;
                if csb == 1 {
                    data[base + kk * nb..base + kk * nb + nb]
                        .copy_from_slice(&b[src..src + nb]);
                } else {
                    for j in 0..nb {
                        data[base + kk * nb + j] = b[src + j * csb];
                    }
                }
            }
            j0 += nb;
        }
        k0 += kb;
    }
    PackedB {
        data: PackData::Owned(data),
        k,
        n,
    }
}

impl PackedB<'_> {
    fn as_slice(&self) -> &[f32] {
        match &self.data {
            PackData::Borrowed(s) => s,
            PackData::Owned(v) => v,
        }
    }

    /// Whether the pack borrowed the input instead of copying (the
    /// zero-copy fast path; exposed for tests and benches).
    pub fn is_borrowed(&self) -> bool {
        matches!(self.data, PackData::Borrowed(_))
    }

    #[inline]
    fn panel(&self, k0: usize, kb: usize, j0: usize, nb: usize) -> &[f32] {
        let base = k0 * self.n + kb * j0;
        &self.as_slice()[base..base + kb * nb]
    }
}

/// Split `[0, m)` into up to `shards` contiguous row ranges aligned to
/// [`MR`] (except the final bound, which is `m`). Deterministic in
/// `(m, shards)`; empty ranges are dropped, so fewer than `shards`
/// entries come back when `m` is small.
pub fn row_shards(m: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.max(1);
    let blocks = m.div_ceil(MR);
    let shards = shards.min(blocks.max(1));
    let per = blocks / shards;
    let extra = blocks % shards;
    let mut out = Vec::with_capacity(shards);
    let mut b0 = 0usize;
    for s in 0..shards {
        let nb = per + usize::from(s < extra);
        let lo = (b0 * MR).min(m);
        let hi = ((b0 + nb) * MR).min(m);
        if hi > lo {
            out.push((lo, hi));
        }
        b0 += nb;
    }
    out
}

/// `C = alpha * A @ B + beta * C`, all row-major:
/// `a`: `m x k`, `b`: `k x n`, `c`: `m x n`. See the module docs for the
/// `alpha`/`beta` contract. Serial: equivalent to [`sgemm_scoped`] with a
/// 1-way scope, and bitwise-identical to it at *any* shard count.
///
/// ```
/// use eindecomp::runtime::gemm::sgemm;
/// let a = [1.0f32, 2.0, 3.0, 4.0]; // 2x2
/// let b = [5.0f32, 6.0, 7.0, 8.0]; // 2x2
/// let mut c = [f32::NAN; 4]; // beta = 0 overwrites, never reads C
/// sgemm(2, 2, 2, 1.0, &a, &b, 0.0, &mut c);
/// assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
/// ```
pub fn sgemm(
    m: usize,
    k: usize,
    n: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    check_dims(m, k, n, a, b, c);
    apply_beta(beta, &mut c[..m * n]);
    if alpha == 0.0 || m == 0 || n == 0 || k == 0 {
        return;
    }
    let bp = pack_b(k, n, b);
    sgemm_rows(0, m, k, n, alpha, a, k, &bp, &mut c[..m * n]);
}

/// Intra-op parallel [`sgemm`]: pack B once, then split the M dimension
/// into `scope.parallelism()` row shards executed via
/// [`ShardScope::fork_join`]. Bitwise-identical to [`sgemm`] for every
/// shard count because shard boundaries are [`MR`]-aligned and each row's
/// update sequence is independent of the split (see module docs).
pub fn sgemm_scoped(
    m: usize,
    k: usize,
    n: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
    scope: &ShardScope,
) {
    check_dims(m, k, n, a, b, c);
    let bp = pack_b(k, n, b);
    sgemm_packed_scoped(m, k, n, alpha, a, k, &bp, beta, c, scope);
}

/// The strided-operand GEMM entry the view-based BMM path uses:
/// `C = alpha * A @ packed(B) + beta * C` where A's rows start `lda`
/// apart (its columns must be unit-stride — that is what lets the
/// micro-kernel read K runs as contiguous slices) and B was packed by
/// [`pack_b`] / [`pack_b_strided`]. Row shards fork-join through `scope`
/// exactly like [`sgemm_scoped`]; results are bitwise-identical to the
/// contiguous path because the per-row update sequence never depends on
/// `lda` or the shard split.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_packed_scoped(
    m: usize,
    k: usize,
    n: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    bp: &PackedB,
    beta: f32,
    c: &mut [f32],
    scope: &ShardScope,
) {
    assert!(
        c.len() >= m * n,
        "sgemm: C has {} elements, need m*n = {}",
        c.len(),
        m * n
    );
    apply_beta(beta, &mut c[..m * n]);
    if alpha == 0.0 || m == 0 || n == 0 || k == 0 {
        return;
    }
    // Tiny problems (work below SHARD_MIN flops-ish) are not worth the
    // fork-join hand-off; the serial path is bitwise-identical anyway.
    let shards = if m * k * n < SHARD_MIN {
        Vec::new()
    } else {
        row_shards(m, scope.parallelism())
    };
    if shards.len() <= 1 {
        sgemm_rows(0, m, k, n, alpha, a, lda, bp, &mut c[..m * n]);
        return;
    }
    let cptr = SyncPtr::new(c.as_mut_ptr());
    scope.fork_join(shards.len(), |s| {
        let (lo, hi) = shards[s];
        let base = cptr.get();
        // SAFETY: shard row ranges are pairwise disjoint, so the derived
        // sub-slices never alias; `c` outlives the fork_join.
        let rows = unsafe { std::slice::from_raw_parts_mut(base.add(lo * n), (hi - lo) * n) };
        sgemm_rows(lo, hi, k, n, alpha, a, lda, bp, rows);
    });
}

/// Compute rows `[m0, m1)` of `C += alpha * A @ packed(B)` (the beta
/// prologue is the caller's job). `c_rows` holds exactly those rows.
/// A's row `i` starts at `a[i * lda]` with unit column stride (`lda = k`
/// for a contiguous A); `m0` must be a multiple of [`MR`] so that
/// row-block boundaries match the serial kernel's — the
/// bitwise-determinism invariant.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_rows(
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    bp: &PackedB,
    c_rows: &mut [f32],
) {
    assert!(m0 <= m1, "sgemm_rows: m0 {m0} > m1 {m1}");
    assert!(m0 % MR == 0, "sgemm_rows: m0 {m0} not aligned to MR {MR}");
    assert!(
        bp.k == k && bp.n == n,
        "sgemm_rows: PackedB is {}x{}, call is {k}x{n}",
        bp.k,
        bp.n
    );
    assert!(
        m1 == 0 || a.len() >= (m1 - 1) * lda + k,
        "sgemm_rows: A has {} elements, need (m1-1)*lda+k = {}",
        a.len(),
        (m1.max(1) - 1) * lda + k
    );
    assert!(
        c_rows.len() >= (m1 - m0) * n,
        "sgemm_rows: C rows have {} elements, need {}",
        c_rows.len(),
        (m1 - m0) * n
    );
    if alpha == 0.0 || n == 0 || k == 0 {
        return;
    }
    // K panels outermost keep one packed KB x n band hot across all row
    // blocks; per-row update order (k0 asc, then j0 asc) is the same as
    // with rows outermost, which is why shard splits cannot change bits.
    let mut k0 = 0;
    while k0 < k {
        let kb = KB.min(k - k0);
        let mut i = m0;
        while i < m1 {
            let ib = MR.min(m1 - i);
            let ri = i - m0;
            let mut j0 = 0;
            while j0 < n {
                let nb = NB.min(n - j0);
                let panel = bp.panel(k0, kb, j0, nb);
                if ib == MR {
                    let a0 = &a[i * lda + k0..i * lda + k0 + kb];
                    let a1 = &a[(i + 1) * lda + k0..(i + 1) * lda + k0 + kb];
                    let a2 = &a[(i + 2) * lda + k0..(i + 2) * lda + k0 + kb];
                    let a3 = &a[(i + 3) * lda + k0..(i + 3) * lda + k0 + kb];
                    let (r01, r23) = c_rows[ri * n..(ri + 4) * n].split_at_mut(2 * n);
                    let (r0, r1) = r01.split_at_mut(n);
                    let (r2, r3) = r23.split_at_mut(n);
                    block4(
                        kb,
                        nb,
                        alpha,
                        [a0, a1, a2, a3],
                        panel,
                        &mut r0[j0..j0 + nb],
                        &mut r1[j0..j0 + nb],
                        &mut r2[j0..j0 + nb],
                        &mut r3[j0..j0 + nb],
                    );
                } else {
                    // Tail rows (< MR, only at i = m - m % MR): axpy per
                    // row. The tail always runs through this path, in any
                    // shard split, so its bits match the serial kernel's.
                    for r in 0..ib {
                        let arow = &a[(i + r) * lda + k0..(i + r) * lda + k0 + kb];
                        let crow = &mut c_rows[(ri + r) * n + j0..(ri + r) * n + j0 + nb];
                        for (kk, &av) in arow.iter().enumerate() {
                            let f = alpha * av;
                            if f != 0.0 {
                                let brow = &panel[kk * nb..kk * nb + nb];
                                for (cv, &bv) in crow.iter_mut().zip(brow) {
                                    *cv += f * bv;
                                }
                            }
                        }
                    }
                }
                j0 += nb;
            }
            i += ib;
        }
        k0 += kb;
    }
}

/// `MR x nb` block update over one packed panel: accumulate `kb` rank-1
/// updates into register tiles, then `c += alpha * acc` once.
#[inline]
fn block4(
    kb: usize,
    nb: usize,
    alpha: f32,
    arows: [&[f32]; MR],
    panel: &[f32],
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
) {
    let [a0, a1, a2, a3] = arows;
    let mut jj = 0;
    while jj + NR <= nb {
        let mut acc = [[0.0f32; NR]; MR];
        for kk in 0..kb {
            let brow = &panel[kk * nb + jj..kk * nb + jj + NR];
            let (v0, v1, v2, v3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
            for t in 0..NR {
                let bv = brow[t];
                acc[0][t] += v0 * bv;
                acc[1][t] += v1 * bv;
                acc[2][t] += v2 * bv;
                acc[3][t] += v3 * bv;
            }
        }
        for t in 0..NR {
            c0[jj + t] += alpha * acc[0][t];
            c1[jj + t] += alpha * acc[1][t];
            c2[jj + t] += alpha * acc[2][t];
            c3[jj + t] += alpha * acc[3][t];
        }
        jj += NR;
    }
    if jj < nb {
        let w = nb - jj;
        let mut acc = [[0.0f32; NR]; MR];
        for kk in 0..kb {
            let brow = &panel[kk * nb + jj..kk * nb + nb];
            let (v0, v1, v2, v3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
            for t in 0..w {
                let bv = brow[t];
                acc[0][t] += v0 * bv;
                acc[1][t] += v1 * bv;
                acc[2][t] += v2 * bv;
                acc[3][t] += v3 * bv;
            }
        }
        for t in 0..w {
            c0[jj + t] += alpha * acc[0][t];
            c1[jj + t] += alpha * acc[1][t];
            c2[jj + t] += alpha * acc[2][t];
            c3[jj + t] += alpha * acc[3][t];
        }
    }
}

/// Shared bounds checks. Real `assert!`s (not `debug_assert!`): release
/// builds would otherwise reach unchecked slice indexing deep inside the
/// panel loops with a confusing panic site.
fn check_dims(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &[f32]) {
    assert!(
        a.len() >= m * k,
        "sgemm: A has {} elements, need m*k = {}",
        a.len(),
        m * k
    );
    assert!(
        b.len() >= k * n,
        "sgemm: B has {} elements, need k*n = {}",
        b.len(),
        k * n
    );
    assert!(
        c.len() >= m * n,
        "sgemm: C has {} elements, need m*n = {}",
        c.len(),
        m * n
    );
}

/// The beta prologue: overwrite on 0, keep on 1, scale otherwise.
fn apply_beta(beta: f32, c: &mut [f32]) {
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for v in c {
            *v *= beta;
        }
    }
}

/// The epilogue counterpart to the `alpha`/`beta` contract: after
/// `C = alpha * (A @ B) + beta * C` lands, apply a chain of pointwise
/// ops to `C` in order. This is where the `fuse-epilogue` IR pass hangs
/// fused elementwise map vertices — the epilogue hits exactly the
/// elements the retired map kernel would have, one op at a time, so the
/// fused result is bitwise-identical to the unfused two-kernel run.
pub fn apply_epilogue(c: &mut [f32], eps: &[crate::einsum::expr::UnaryOp]) {
    for e in eps {
        for v in c.iter_mut() {
            *v = e.apply(*v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from_u64(seed);
        (0..n).map(|_| r.next_centered()).collect()
    }

    #[test]
    fn matches_naive_various_shapes() {
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (4, 4, 4),
            (8, 3, 9),
            (17, 13, 11),
            (5, 64, 2),
            (33, 300, 19),
            (70, 7, 290),
        ] {
            let a = rand_vec(m * k, 1);
            let b = rand_vec(k * n, 2);
            let want = naive(m, k, n, &a, &b);
            let mut c = vec![0.0f32; m * n];
            sgemm(m, k, n, 1.0, &a, &b, 0.0, &mut c);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn alpha_beta_semantics() {
        let (m, k, n) = (4, 3, 5);
        let a = rand_vec(m * k, 3);
        let b = rand_vec(k * n, 4);
        let c0 = rand_vec(m * n, 5);
        let mut c = c0.clone();
        sgemm(m, k, n, 2.0, &a, &b, 0.5, &mut c);
        let ab = naive(m, k, n, &a, &b);
        for i in 0..m * n {
            let want = 2.0 * ab[i] + 0.5 * c0[i];
            assert!((c[i] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn beta_zero_overwrites_nan() {
        let (m, k, n) = (2, 2, 2);
        let a = rand_vec(m * k, 6);
        let b = rand_vec(k * n, 7);
        let mut c = vec![f32::NAN; m * n];
        sgemm(m, k, n, 1.0, &a, &b, 0.0, &mut c);
        assert!(c.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn zero_dims_are_noops() {
        let mut c = vec![1.0f32; 0];
        sgemm(0, 3, 0, 1.0, &[], &[], 0.0, &mut c);
    }

    #[test]
    #[should_panic(expected = "sgemm: A has")]
    fn short_a_rejected_in_release_too() {
        let mut c = vec![0.0f32; 4];
        sgemm(2, 2, 2, 1.0, &[1.0; 3], &[1.0; 4], 0.0, &mut c);
    }

    #[test]
    fn row_shards_cover_and_align() {
        for m in [0usize, 1, 3, 4, 5, 7, 8, 64, 101, 1000] {
            for s in [1usize, 2, 3, 7, 8, 64] {
                let shards = row_shards(m, s);
                let mut next = 0;
                for &(lo, hi) in &shards {
                    assert_eq!(lo, next, "gap at m={m} s={s}");
                    assert!(lo < hi);
                    assert_eq!(lo % MR, 0, "unaligned start m={m} s={s}");
                    next = hi;
                }
                assert_eq!(next, m, "not covered m={m} s={s}");
                assert!(shards.len() <= s.max(1));
            }
        }
    }

    #[test]
    fn packed_panels_roundtrip() {
        // pack_b is a pure relayout: every (kk, j) lands in exactly one
        // panel cell. n = 270 exercises the owned multi-panel path; the
        // n <= NB borrowed fast path is checked separately below.
        let (k, n) = (300, 270);
        let b = rand_vec(k * n, 8);
        let bp = pack_b(k, n, &b);
        let mut k0 = 0;
        while k0 < k {
            let kb = KB.min(k - k0);
            let mut j0 = 0;
            while j0 < n {
                let nb = NB.min(n - j0);
                let panel = bp.panel(k0, kb, j0, nb);
                for kk in 0..kb {
                    for j in 0..nb {
                        assert_eq!(panel[kk * nb + j], b[(k0 + kk) * n + j0 + j]);
                    }
                }
                j0 += nb;
            }
            k0 += kb;
        }
    }

    #[test]
    fn narrow_b_pack_borrows_and_matches_owned_layout() {
        // n <= NB: the borrowed fast path must expose the exact same
        // panels the copying path would build.
        let (k, n) = (300, 128);
        let b = rand_vec(k * n, 12);
        let bp = pack_b(k, n, &b);
        assert!(bp.is_borrowed());
        let mut k0 = 0;
        while k0 < k {
            let kb = KB.min(k - k0);
            let panel = bp.panel(k0, kb, 0, n);
            for kk in 0..kb {
                for j in 0..n {
                    assert_eq!(panel[kk * n + j], b[(k0 + kk) * n + j]);
                }
            }
            k0 += kb;
        }
    }

    #[test]
    fn sharded_rows_equal_serial_bitwise() {
        // In-module smoke of the invariant tests/gemm_parallel.rs sweeps:
        // running the row shards serially, in any order, is bit-equal to
        // one (0, m) pass.
        let (m, k, n) = (37, 65, 41);
        let a = rand_vec(m * k, 9);
        let b = rand_vec(k * n, 10);
        let bp = pack_b(k, n, &b);
        let mut serial = vec![0.0f32; m * n];
        sgemm_rows(0, m, k, n, 1.0, &a, k, &bp, &mut serial);
        for shards in [2usize, 3, 8] {
            let mut c = vec![0.0f32; m * n];
            let mut ranges = row_shards(m, shards);
            ranges.reverse(); // order must not matter
            for (lo, hi) in ranges {
                sgemm_rows(lo, hi, k, n, 1.0, &a, k, &bp, &mut c[lo * n..hi * n]);
            }
            assert_eq!(c, serial, "shards {shards}");
        }
    }

    #[test]
    fn strided_pack_matches_contiguous_pack() {
        // A k x n operand embedded in a larger row-major buffer (rsb > n)
        // and a transposed one (csb > 1) must pack to the same panels as
        // packing a materialized contiguous copy.
        let (k, n) = (37, 290); // n > NB: owned multi-panel path
        let (big_rows, big_cols) = (k + 5, n + 11);
        let big = rand_vec(big_rows * big_cols, 21);
        // contiguous copy of the top-left k x n window
        let mut dense = vec![0.0f32; k * n];
        for kk in 0..k {
            dense[kk * n..kk * n + n].copy_from_slice(&big[kk * big_cols..kk * big_cols + n]);
        }
        let want = pack_b(k, n, &dense);
        let got = pack_b_strided(k, n, &big, big_cols, 1);
        assert_eq!(want.as_slice(), got.as_slice());
        // transposed window: element (kk, j) at big[j * big_cols + kk]
        let (kt, nt) = (29, 31);
        let mut dense_t = vec![0.0f32; kt * nt];
        for kk in 0..kt {
            for j in 0..nt {
                dense_t[kk * nt + j] = big[j * big_cols + kk];
            }
        }
        let want_t = pack_b(kt, nt, &dense_t);
        let got_t = pack_b_strided(kt, nt, &big, 1, big_cols);
        assert_eq!(want_t.as_slice(), got_t.as_slice());
    }

    #[test]
    fn lda_gemm_matches_contiguous_gemm_bitwise() {
        // A embedded with lda > k must give bit-identical C to the
        // contiguous kernel on a materialized copy of A.
        let (m, k, n) = (23, 41, 57);
        let lda = k + 9;
        let abig = rand_vec(m * lda, 22);
        let mut adense = vec![0.0f32; m * k];
        for i in 0..m {
            adense[i * k..i * k + k].copy_from_slice(&abig[i * lda..i * lda + k]);
        }
        let b = rand_vec(k * n, 23);
        let mut want = vec![0.0f32; m * n];
        sgemm(m, k, n, 1.0, &adense, &b, 0.0, &mut want);
        let bp = pack_b(k, n, &b);
        let mut got = vec![0.0f32; m * n];
        crate::util::with_intra_op_pool(1, |scope| {
            sgemm_packed_scoped(m, k, n, 1.0, &abig, lda, &bp, 0.0, &mut got, scope);
        });
        assert_eq!(got, want);
        // and under row sharding
        let mut got4 = vec![0.0f32; m * n];
        crate::util::with_intra_op_pool(4, |scope| {
            sgemm_packed_scoped(m, k, n, 1.0, &abig, lda, &bp, 0.0, &mut got4, scope);
        });
        assert_eq!(got4, want);
    }
}

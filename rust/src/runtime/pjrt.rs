//! PJRT kernel engine: registry for the HLO-text artifacts produced by the
//! python/jax compile path (`make artifacts`).
//!
//! The interchange format is HLO *text* described by
//! `artifacts/manifest.txt`, one line per (kind, shape) kernel:
//! `name<TAB>kind<TAB>d0,d1,..<TAB>file` (aot.py also emits a
//! human-oriented manifest.json; rust parses only the text form to stay
//! dependency-free).
//!
//! This build is **dependency-free**: the `xla` FFI crate that executes
//! the compiled HLO is not available, so [`PjrtEngine`] degrades to a
//! manifest registry. [`PjrtEngine::runtime_available`] reports whether
//! execution is possible (`false` here); [`PjrtEngine::try_eval`] then
//! always returns `Ok(None)` so [`super::DispatchEngine`] with
//! [`super::Backend::Auto`] transparently falls back to the native
//! kernels, and `run`/`eval` return an [`Error::Runtime`] explaining the
//! missing FFI. Artifact-dependent tests gate on `runtime_available()`.

use super::KernelEngine;
use crate::einsum::expr::EinSum;
use crate::error::{Error, Result};
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One artifact in `manifest.txt`.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    /// Kernel kind: `bmm`, `ew_add`, `ew_mul`, `ew_sub`, `ew_div`,
    /// `map_exp`, `map_relu`, `map_silu`, `reduce_sum_last`,
    /// `reduce_max_last`, `softmax`, `attention_tile`, ...
    pub kind: String,
    /// Shape parameters, kind-specific (e.g. `[b, m, k, n]` for `bmm`).
    pub dims: Vec<usize>,
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
}

/// Parse the line-oriented manifest format.
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split('\t').collect();
        if parts.len() != 4 {
            return Err(Error::Artifact(format!(
                "manifest line {}: expected 4 tab-separated fields, got {}",
                lineno + 1,
                parts.len()
            )));
        }
        let dims = parts[2]
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse::<usize>().map_err(|_| {
                    Error::Artifact(format!("manifest line {}: bad dim {s:?}", lineno + 1))
                })
            })
            .collect::<Result<Vec<_>>>()?;
        out.push(ManifestEntry {
            name: parts[0].to_string(),
            kind: parts[1].to_string(),
            dims,
            file: parts[3].to_string(),
        });
    }
    Ok(out)
}

/// PJRT artifact registry (execution stubbed; see module docs).
pub struct PjrtEngine {
    /// (kind, dims) -> manifest entry, for fast availability checks.
    index: HashMap<(String, Vec<usize>), ManifestEntry>,
    dir: PathBuf,
}

impl PjrtEngine {
    /// Load the artifact manifest from `dir` (e.g. `artifacts/`). Fails if
    /// the manifest is missing or unreadable.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let mut index = HashMap::new();
        for k in parse_manifest(&text)? {
            index.insert((k.kind.clone(), k.dims.clone()), k);
        }
        Ok(PjrtEngine { index, dir })
    }

    /// Whether this build can actually execute compiled HLO. Always
    /// `false` without the `xla` FFI; tests and benches that need real
    /// PJRT execution must gate on this.
    pub fn runtime_available() -> bool {
        false
    }

    /// Directory the manifest was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of registered artifacts.
    pub fn num_artifacts(&self) -> usize {
        self.index.len()
    }

    /// True if an artifact for (kind, dims) exists in the manifest.
    pub fn has(&self, kind: &str, dims: &[usize]) -> bool {
        self.index.contains_key(&(kind.to_string(), dims.to_vec()))
    }

    /// Manifest entry for (kind, dims), if registered.
    pub fn entry(&self, kind: &str, dims: &[usize]) -> Option<&ManifestEntry> {
        self.index.get(&(kind.to_string(), dims.to_vec()))
    }

    /// Execute the named-kind kernel. Unavailable in this build.
    pub fn run(&self, kind: &str, dims: &[usize], _inputs: &[&Tensor]) -> Result<Tensor> {
        if self.entry(kind, dims).is_none() {
            return Err(Error::Artifact(format!(
                "no artifact for kind={kind} dims={dims:?}"
            )));
        }
        Err(Error::Runtime(
            "PJRT execution unavailable: this build has no xla FFI (dependency-free crate); \
             use the native engine"
                .into(),
        ))
    }

    /// Try to evaluate an EinSum via a registered artifact. Without an
    /// executing runtime this always returns `Ok(None)`, which makes
    /// `Backend::Auto` fall back to the native engine.
    pub fn try_eval(&self, _op: &EinSum, _inputs: &[&Tensor]) -> Result<Option<Tensor>> {
        Ok(None)
    }
}

impl KernelEngine for PjrtEngine {
    fn eval(&self, op: &EinSum, inputs: &[&Tensor]) -> Result<Tensor> {
        match self.try_eval(op, inputs)? {
            Some(t) => Ok(t),
            None => Err(Error::Artifact(format!(
                "no PJRT artifact matches op {op} on shapes {:?} (runtime available: {})",
                inputs.iter().map(|t| t.shape()).collect::<Vec<_>>(),
                Self::runtime_available()
            ))),
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_rejects() {
        let entries =
            parse_manifest("# comment\nk1\tbmm\t1,64,64,64\tk1.hlo\nk2\tew_add\t1024\tk2.hlo\n")
                .unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].kind, "bmm");
        assert_eq!(entries[0].dims, vec![1, 64, 64, 64]);
        assert!(parse_manifest("only\ttwo\n").is_err());
        assert!(parse_manifest("a\tb\tnot-a-dim\tf\n").is_err());
    }

    #[test]
    fn engine_load_from_manifest_dir() {
        let dir = std::env::temp_dir()
            .join(format!("eindecomp_pjrt_stub_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "k1\tbmm\t1,8,8,8\tk1.hlo\n").unwrap();
        let e = PjrtEngine::load(&dir).unwrap();
        assert_eq!(e.num_artifacts(), 1);
        assert!(e.has("bmm", &[1, 8, 8, 8]));
        assert!(!e.has("bmm", &[2, 8, 8, 8]));
        // execution is stubbed out in the dependency-free build
        assert!(!PjrtEngine::runtime_available());
        let t = Tensor::zeros(&[8, 8]);
        assert!(e.run("bmm", &[1, 8, 8, 8], &[&t, &t]).is_err());
        let op = EinSum::contraction(
            crate::einsum::label::labels("i j"),
            crate::einsum::label::labels("j k"),
            crate::einsum::label::labels("i k"),
        );
        assert!(e.try_eval(&op, &[&t, &t]).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_fails() {
        assert!(PjrtEngine::load("/nonexistent/artifacts").is_err());
    }
}

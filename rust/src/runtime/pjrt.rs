//! PJRT kernel engine: loads HLO-text artifacts produced by the python/jax
//! compile path (`make artifacts`) and executes them on the PJRT CPU
//! client via the `xla` crate.
//!
//! The interchange format is HLO *text*, not a serialized `HloModuleProto`:
//! jax >= 0.5 emits protos with 64-bit instruction ids that the crate's
//! XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md).
//!
//! Artifacts are described by `artifacts/manifest.txt`, one line per
//! (kind, shape) kernel: `name<TAB>kind<TAB>d0,d1,..<TAB>file` (aot.py
//! also emits a human-oriented manifest.json; rust parses only the text
//! form to stay dependency-free). Executables are compiled lazily on
//! first use and cached. Python never runs on this path — the manifest
//! plus HLO files are all that is needed at run time.

use super::KernelEngine;
use crate::einsum::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use crate::einsum::label::{Label, LabelList};
use crate::error::{Error, Result};
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One artifact in `manifest.txt`.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    /// Kernel kind: `bmm`, `ew_add`, `ew_mul`, `ew_sub`, `ew_div`,
    /// `map_exp`, `map_relu`, `map_silu`, `reduce_sum_last`,
    /// `reduce_max_last`, `softmax`, `attention_tile`, ...
    pub kind: String,
    /// Shape parameters, kind-specific (e.g. `[b, m, k, n]` for `bmm`).
    pub dims: Vec<usize>,
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
}

/// Parse the line-oriented manifest format.
fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split('\t').collect();
        if parts.len() != 4 {
            return Err(Error::Artifact(format!(
                "manifest line {}: expected 4 tab-separated fields, got {}",
                lineno + 1,
                parts.len()
            )));
        }
        let dims = parts[2]
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse::<usize>().map_err(|_| {
                    Error::Artifact(format!("manifest line {}: bad dim {s:?}", lineno + 1))
                })
            })
            .collect::<Result<Vec<_>>>()?;
        out.push(ManifestEntry {
            name: parts[0].to_string(),
            kind: parts[1].to_string(),
            dims,
            file: parts[3].to_string(),
        });
    }
    Ok(out)
}

/// Compiled-executable cache entry.
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT-backed kernel engine.
///
/// All PJRT interaction is serialized behind one mutex: the CPU client's
/// executables are internally multi-threaded, and the FFI types are not
/// `Sync`. Wall-clock parallel-speedup experiments therefore use the
/// native engine; the PJRT engine demonstrates the AOT path and provides
/// the XLA-compiled hot kernels for single-stream throughput.
pub struct PjrtEngine {
    inner: Mutex<PjrtInner>,
    /// (kind, dims) -> manifest entry, for fast availability checks.
    index: HashMap<(String, Vec<usize>), ManifestEntry>,
    dir: PathBuf,
}

struct PjrtInner {
    client: xla::PjRtClient,
    cache: HashMap<String, Compiled>,
}

// SAFETY: every access to the FFI client/executables goes through the
// mutex in `inner`; the raw pointers are never shared across threads
// without it.
unsafe impl Send for PjrtEngine {}
unsafe impl Sync for PjrtEngine {}

impl PjrtEngine {
    /// Load the artifact manifest from `dir` (e.g. `artifacts/`) and create
    /// a PJRT CPU client. Fails if the manifest is missing or unreadable.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let mut index = HashMap::new();
        for k in parse_manifest(&text)? {
            index.insert((k.kind.clone(), k.dims.clone()), k);
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtEngine {
            inner: Mutex::new(PjrtInner {
                client,
                cache: HashMap::new(),
            }),
            index,
            dir,
        })
    }

    /// Number of registered artifacts.
    pub fn num_artifacts(&self) -> usize {
        self.index.len()
    }

    /// True if an artifact for (kind, dims) exists.
    pub fn has(&self, kind: &str, dims: &[usize]) -> bool {
        self.index.contains_key(&(kind.to_string(), dims.to_vec()))
    }

    /// Execute the named-kind kernel on flat input buffers with explicit
    /// shapes. Inputs/outputs are f32 tensors; the artifact must have been
    /// lowered with `return_tuple=True` (we unwrap a 1-tuple).
    pub fn run(&self, kind: &str, dims: &[usize], inputs: &[&Tensor]) -> Result<Tensor> {
        let entry = self
            .index
            .get(&(kind.to_string(), dims.to_vec()))
            .ok_or_else(|| {
                Error::Artifact(format!("no artifact for kind={kind} dims={dims:?}"))
            })?
            .clone();
        let mut inner = self.inner.lock().unwrap();
        if !inner.cache.contains_key(&entry.name) {
            let path = self.dir.join(&entry.file);
            let proto =
                xla::HloModuleProto::from_text_file(path.to_str().ok_or_else(|| {
                    Error::Artifact(format!("non-utf8 path {}", path.display()))
                })?)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner.client.compile(&comp)?;
            inner.cache.insert(entry.name.clone(), Compiled { exe });
        }
        let compiled = inner.cache.get(&entry.name).unwrap();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims_i64: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data()).reshape(&dims_i64)
            })
            .collect::<std::result::Result<Vec<_>, _>>()?;
        let result = compiled.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let shape = out.array_shape()?;
        let out_dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let values = out.to_vec::<f32>()?;
        Tensor::new(out_dims, values)
    }

    /// Try to evaluate an EinSum via a registered artifact. Returns
    /// `Ok(None)` when no artifact pattern matches (caller falls back).
    pub fn try_eval(&self, op: &EinSum, inputs: &[&Tensor]) -> Result<Option<Tensor>> {
        match op {
            EinSum::Input => Ok(None),
            EinSum::Unary { lx, lz, op: u, agg } => {
                self.try_eval_unary(lx, lz, *u, *agg, inputs[0])
            }
            EinSum::Binary {
                lx,
                ly,
                lz,
                join,
                agg,
            } => self.try_eval_binary(lx, ly, lz, *join, *agg, inputs),
        }
    }

    fn try_eval_unary(
        &self,
        lx: &LabelList,
        lz: &LabelList,
        u: UnaryOp,
        agg: AggOp,
        x: &Tensor,
    ) -> Result<Option<Tensor>> {
        // Pure map in the same label order: flatten to [n].
        if lz == lx {
            let kind = match u {
                UnaryOp::Exp => "map_exp",
                UnaryOp::Relu => "map_relu",
                UnaryOp::Silu => "map_silu",
                UnaryOp::Square => "map_square",
                _ => return Ok(None),
            };
            let n = x.len();
            if !self.has(kind, &[n]) {
                return Ok(None);
            }
            let flat = x.clone().reshape(vec![n])?;
            let out = self.run(kind, &[n], &[&flat])?;
            return Ok(Some(out.reshape(x.shape().to_vec())?));
        }
        // Row reduction over the last label: [rows, cols] -> [rows].
        if lz.len() + 1 == lx.len() && lz[..] == lx[..lz.len()] && x.rank() >= 1 {
            let kind = match agg {
                AggOp::Sum => "reduce_sum_last",
                AggOp::Max => "reduce_max_last",
                _ => return Ok(None),
            };
            if !matches!(u, UnaryOp::Identity) {
                return Ok(None);
            }
            let cols = *x.shape().last().unwrap();
            let rows = x.len() / cols.max(1);
            if !self.has(kind, &[rows, cols]) {
                return Ok(None);
            }
            let flat = x.clone().reshape(vec![rows, cols])?;
            let out = self.run(kind, &[rows, cols], &[&flat])?;
            let out_shape: Vec<usize> = x.shape()[..x.rank() - 1].to_vec();
            return Ok(Some(out.reshape(out_shape)?));
        }
        Ok(None)
    }

    fn try_eval_binary(
        &self,
        lx: &LabelList,
        ly: &LabelList,
        lz: &LabelList,
        join: JoinOp,
        agg: AggOp,
        inputs: &[&Tensor],
    ) -> Result<Option<Tensor>> {
        let (x, y) = (inputs[0], inputs[1]);
        // Elementwise, identical label order: flatten to [n].
        if lx == ly && lx == lz {
            let kind = match join {
                JoinOp::Add => "ew_add",
                JoinOp::Mul => "ew_mul",
                JoinOp::Sub => "ew_sub",
                JoinOp::Div => "ew_div",
                _ => return Ok(None),
            };
            let n = x.len();
            if !self.has(kind, &[n]) {
                return Ok(None);
            }
            let fx = x.clone().reshape(vec![n])?;
            let fy = y.clone().reshape(vec![n])?;
            let out = self.run(kind, &[n], &[&fx, &fy])?;
            return Ok(Some(out.reshape(x.shape().to_vec())?));
        }
        // Mul/Sum contraction with a clean batch/m/n/k split: canonical BMM.
        if join == JoinOp::Mul && agg == AggOp::Sum {
            if let Some((bmnk, perm_x, perm_y, z_canon, z_shape)) =
                bmm_canonicalize(lx, ly, lz, x, y)
            {
                let [b, m, k, n] = bmnk;
                if !self.has("bmm", &[b, m, k, n]) {
                    return Ok(None);
                }
                let xc = x.permute(&perm_x)?.reshape(vec![b, m, k])?;
                let yc = y.permute(&perm_y)?.reshape(vec![b, k, n])?;
                let out = self.run("bmm", &[b, m, k, n], &[&xc, &yc])?;
                let out = out.reshape(z_shape)?;
                let perm_z: Vec<usize> = lz
                    .iter()
                    .map(|l| z_canon.iter().position(|m2| m2 == l).unwrap())
                    .collect();
                return Ok(Some(out.permute(&perm_z)?));
            }
        }
        Ok(None)
    }
}

/// Classify a Mul/Sum binary EinSum into the canonical BMM form. Returns
/// `([b,m,k,n], perm_x, perm_y, canonical z labels, canonical z shape)`.
#[allow(clippy::type_complexity)]
fn bmm_canonicalize(
    lx: &LabelList,
    ly: &LabelList,
    lz: &LabelList,
    x: &Tensor,
    y: &Tensor,
) -> Option<([usize; 4], Vec<usize>, Vec<usize>, LabelList, Vec<usize>)> {
    let mut batch = vec![];
    let mut ms = vec![];
    let mut ns = vec![];
    let mut ks = vec![];
    let mut seen: Vec<Label> = vec![];
    for l in lx.iter().chain(ly.iter()) {
        if seen.contains(l) {
            continue;
        }
        seen.push(*l);
        match (lx.contains(l), ly.contains(l), lz.contains(l)) {
            (true, true, true) => batch.push(*l),
            (true, false, true) => ms.push(*l),
            (false, true, true) => ns.push(*l),
            (true, true, false) => ks.push(*l),
            _ => return None,
        }
    }
    let dim_x = |l: &Label| x.shape()[lx.iter().position(|m| m == l).unwrap()];
    let dim_y = |l: &Label| y.shape()[ly.iter().position(|m| m == l).unwrap()];
    let b: usize = batch.iter().map(dim_x).product();
    let m: usize = ms.iter().map(dim_x).product();
    let k: usize = ks.iter().map(dim_x).product();
    let n: usize = ns.iter().map(dim_y).product();
    let x_order: LabelList = batch.iter().chain(&ms).chain(&ks).copied().collect();
    let y_order: LabelList = batch.iter().chain(&ks).chain(&ns).copied().collect();
    let perm_x: Vec<usize> = x_order
        .iter()
        .map(|l| lx.iter().position(|m2| m2 == l).unwrap())
        .collect();
    let perm_y: Vec<usize> = y_order
        .iter()
        .map(|l| ly.iter().position(|m2| m2 == l).unwrap())
        .collect();
    let z_canon: LabelList = batch.iter().chain(&ms).chain(&ns).copied().collect();
    let z_shape: Vec<usize> = batch
        .iter()
        .map(dim_x)
        .chain(ms.iter().map(dim_x))
        .chain(ns.iter().map(dim_y))
        .collect();
    Some(([b, m, k, n], perm_x, perm_y, z_canon, z_shape))
}

impl KernelEngine for PjrtEngine {
    fn eval(&self, op: &EinSum, inputs: &[&Tensor]) -> Result<Tensor> {
        match self.try_eval(op, inputs)? {
            Some(t) => Ok(t),
            None => Err(Error::Artifact(format!(
                "no PJRT artifact matches op {op} on shapes {:?}",
                inputs.iter().map(|t| t.shape()).collect::<Vec<_>>()
            ))),
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

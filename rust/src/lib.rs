//! # eindecomp
//!
//! A reproduction of *EinDecomp: Decomposition of Declaratively-Specified
//! Machine Learning and Numerical Computations for Parallel Execution*
//! (Bourgeois et al., PVLDB 2024).
//!
//! The library is organised around the paper's pipeline:
//!
//! ```text
//!   EinSum program (einsum::)          -- declarative spec, a DAG of EinSum ops
//!     -> EinDecomp planner (decomp::)  -- choose a partitioning vector per vertex
//!     -> TRA IR (tra::program)         -- the Eq.-5 relational program, reified:
//!                                         Partition/ReKey/Join/Aggregate/
//!                                         Repartition/Assemble over typed relations
//!     -> passes (tra::passes)          -- ordered, toggleable rewrites with a
//!                                         change log (identity-repart elision,
//!                                         refinement aliasing, agg reduction
//!                                         trees, dead-relation elimination)
//!     -> TaskGraph (taskgraph::)       -- emit kernel calls + transfers, place
//!     -> simulated cluster (sim::)     -- p workers, byte-accurate network model,
//!                                         real execution via a nested work-stealing
//!                                         scheduler (util::execute_dag_scoped):
//!                                         idle workers steal whole tasks AND
//!                                         intra-op shards of running kernels
//!     -> kernels (runtime::)           -- pure-rust native kernels (in-tree packed
//!                                         intra-op GEMM); the PJRT artifact path is
//!                                         a registry-only stub in this build
//! ```
//!
//! The IR mid-layer is a public API: `Executable::tra_program()` exposes
//! the optimized program behind any compiled artifact,
//! `Session::explain` pretty-prints it with the pass change log and the
//! modeled byte ledger, and `--passes` / the `explain` subcommand
//! surface both on the CLI.
//!
//! The data plane between those stages is zero-copy: partitioning
//! produces strided [`tensor::TensorView`] tiles in O(1), kernels read
//! operands through view strides (packing B straight from the strided
//! tile), repartitioned tiles alias their producer when contained in it,
//! and output/scratch buffers recycle through a per-worker
//! [`util::BufferPool`].
//!
//! The public entry point is the **compile-once / run-many**
//! [`coordinator::session::Session`]: graphs are declared lazily with
//! chainable [`einsum::lazy::Expr`] handles (or built directly as
//! [`einsum::graph::EinGraph`]s), compiled exactly once into an
//! [`coordinator::session::Executable`] (plan → lower → place), and then
//! executed any number of times with zero planner/lowering work per call.
//! Compiles are cached under a canonical graph signature
//! ([`einsum::canon`]), so label-renamed / vertex-reordered but
//! semantically identical programs share one plan.
//!
//! End to end, in code — declare, compile once, run many, verify:
//!
//! ```
//! use eindecomp::prelude::*;
//! use std::collections::HashMap;
//!
//! // Declare lazily: Z[i,k] = sum_j A[i,j] * B[j,k] over 32x32 inputs.
//! let session = Session::new(DriverConfig { workers: 2, p: 2, ..Default::default() })?;
//! let a = session.input("A", &[32, 32]);
//! let b = session.input("B", &[32, 32]);
//! let z = a.einsum("ij,jk->ik", &b)?;
//!
//! // Compile once: plan + lower + place, frozen into an Executable.
//! let exe = session.compile_expr(&z)?;
//! assert_eq!(exe.provenance(), PlanProvenance::Planned);
//!
//! // Run many: zero planner and zero lowering work per call.
//! let mut inputs = HashMap::new();
//! inputs.insert(a.id(), Tensor::random(&[32, 32], 1));
//! inputs.insert(b.id(), Tensor::random(&[32, 32], 2));
//! for _ in 0..3 {
//!     let (outs, report) = exe.run(&inputs)?;
//!     assert_eq!(outs[&z.id()].shape(), &[32, 32]);
//!     assert!(report.exec.kernel_calls >= 2);
//! }
//!
//! // A canonically-equivalent program (renamed labels and tensors) is a
//! // cache hit: no second planning pass.
//! let x = session.input("X", &[32, 32]);
//! let y = session.input("Y", &[32, 32]);
//! let w = x.einsum("pq,qr->pr", &y)?;
//! let exe2 = session.compile_expr(&w)?;
//! assert_eq!(exe2.provenance(), PlanProvenance::CacheHit);
//! assert_eq!(session.stats().planner_runs, 1);
//! # Ok::<(), eindecomp::Error>(())
//! ```
//!
//! The legacy [`coordinator::driver::Driver`] remains as a thin shim with
//! the old plan-on-every-call semantics. For multi-tenant deployments,
//! [`serve::Server`] wraps one shared session with admission control, a
//! per-tenant fair queue, a fixed serving pool, and signature-keyed
//! dynamic batching whose coalesced executions are bitwise-identical to
//! solo runs (see the [`serve`] module docs).
//!
//! The tensor-relational algebra of the paper (join / aggregation /
//! repartition over *tensor relations*) lives in [`tra`]; model builders
//! (matrix chains, FFNN training, multi-head attention, LLaMA-style
//! transformer graphs) live in [`models`]; the experiment drivers that
//! regenerate every figure of the paper's evaluation live under
//! `rust/benches/`.
//!
//! ## Tier-1 verify → cargo invocations
//!
//! The repo's tier-1 verification is exactly:
//!
//! ```sh
//! cargo build --release && cargo test -q
//! ```
//!
//! run from `rust/`. That covers the library, the `eindecomp` binary, the
//! integration/property suites under `rust/tests/` (PJRT-dependent cases
//! skip unless artifacts *and* an executing runtime are present), and
//! compiles the examples declared in `Cargo.toml`. The figure benches are
//! plain `fn main()` drivers with `test = false` (so `cargo test` never
//! executes the full sweeps): `cargo bench --bench <name>`, or
//! `rust/scripts/bench_smoke.sh` for a capped smoke pass. The crate
//! is intentionally dependency-free — `util` hand-rolls the RNG, the JSON
//! writer, and the scheduler instead of pulling rand/serde/rayon.

pub mod coordinator;
pub mod data;
pub mod decomp;
pub mod einsum;
pub mod error;
pub mod models;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod taskgraph;
pub mod tensor;
pub mod tra;
pub mod util;

pub use error::{Error, Result};

/// Crate-wide convenience prelude for examples and benches.
pub mod prelude {
    pub use crate::coordinator::driver::{Driver, DriverConfig, PlanProvenance, RunReport};
    pub use crate::coordinator::session::{CacheStats, Executable, Explain, Session};
    pub use crate::decomp::{
        baselines::Strategy, cost::CostModel, plan_graph, Plan, PlannerConfig,
    };
    pub use crate::einsum::{
        canon::{canonicalize, Canon, CanonSignature},
        expr::{AggOp, EinSum, JoinOp, UnaryOp},
        graph::{EinGraph, VertexId},
        label::{labels, Label},
        lazy::Expr,
    };
    pub use crate::error::{
        Error, ExecCause, ExecError, LowerError, PlanError, Result, ServeCause, ServeError,
    };
    pub use crate::runtime::{Backend, KernelEngine, MemoryBudget};
    pub use crate::serve::{
        output_checksum, run_load, LatencySummary, LoadConfig, LoadReport, Response, ServeConfig,
        ServeStats, Server, Ticket,
    };
    pub use crate::sim::cluster::{Cluster, ExecMode, ExecReport};
    pub use crate::sim::faults::{FaultKind, FaultPlan, RunOptions};
    pub use crate::sim::network::{LinkClass, NetworkProfile, Topology};
    pub use crate::taskgraph::TaskGraph;
    pub use crate::tensor::{Tensor, TensorView};
    pub use crate::tra::passes::{PassKind, PassLog, PassManager, PassSelector};
    pub use crate::tra::program::{
        from_plan, CollectiveSchedule, RelId, RelSchema, ResidencyStats, TraOp, TraProgram,
    };
    pub use crate::tra::relation::TensorRelation;
    pub use crate::util::BufferPool;
}

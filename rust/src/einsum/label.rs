//! Labels and label lists.
//!
//! A *label* is a symbol that indexes a tensor dimension (the `i`, `j`, `k`
//! of `Z_ik <- sum_j X_ij * Y_jk`). Labels are interned into `u32` handles
//! so that label lists are cheap to copy, hash, and compare — the planner
//! manipulates millions of them while enumerating partitionings.
//!
//! The key primitive on label lists is the paper's project/permute
//! operation `b[l1; l2]` ([`project`]): build a vector of length `|l1|`
//! whose `i`-th entry is `b[j]` where `l1[i] == l2[j]`.

use std::collections::HashMap;
use std::fmt;
use std::sync::{OnceLock, RwLock};

/// Global label interner: name -> id and id -> name.
struct Interner {
    by_name: HashMap<String, u32>,
    names: Vec<String>,
}

// std-only lazy global (no `once_cell` in this crate).
static INTERNER_CELL: OnceLock<RwLock<Interner>> = OnceLock::new();

#[allow(non_snake_case)]
fn INTERNER() -> &'static RwLock<Interner> {
    INTERNER_CELL.get_or_init(|| {
        RwLock::new(Interner {
            by_name: HashMap::new(),
            names: Vec::new(),
        })
    })
}

/// An interned dimension label.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Label(pub u32);

impl Label {
    /// Intern a label by name. The same name always returns the same id.
    pub fn new(name: &str) -> Label {
        {
            let g = INTERNER().read().unwrap();
            if let Some(&id) = g.by_name.get(name) {
                return Label(id);
            }
        }
        let mut g = INTERNER().write().unwrap();
        if let Some(&id) = g.by_name.get(name) {
            return Label(id);
        }
        let id = g.names.len() as u32;
        g.names.push(name.to_string());
        g.by_name.insert(name.to_string(), id);
        Label(id)
    }

    /// The interned name.
    pub fn name(&self) -> String {
        INTERNER().read().unwrap().names[self.0 as usize].clone()
    }
}

impl fmt::Debug for Label {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

impl fmt::Display for Label {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Convenience: intern a whitespace- or comma-separated list of label names.
///
/// ```
/// use eindecomp::einsum::label::labels;
/// let l = labels("i j k");
/// assert_eq!(l.len(), 3);
/// ```
pub fn labels(spec: &str) -> Vec<Label> {
    spec.split(|c: char| c.is_whitespace() || c == ',')
        .filter(|s| !s.is_empty())
        .map(Label::new)
        .collect()
}

/// A list (vector) of labels — `l_X` in the paper.
pub type LabelList = Vec<Label>;

/// The paper's `b[l1; l2]` operation: project/permute `values` (parallel to
/// `l2`) onto the order given by `l1`. Entry `i` of the result is
/// `values[j]` for the first `j` with `l1[i] == l2[j]`.
///
/// Example from the paper: `b = [2,3,4]`, `l1 = [k,i]`, `l2 = [i,j,k]`
/// gives `[4,2]`.
pub fn project<T: Copy>(values: &[T], l1: &[Label], l2: &[Label]) -> Vec<T> {
    debug_assert_eq!(values.len(), l2.len(), "values must parallel l2");
    l1.iter()
        .map(|a| {
            let j = l2
                .iter()
                .position(|b| b == a)
                .unwrap_or_else(|| panic!("label {a} not found in {l2:?}"));
            values[j]
        })
        .collect()
}

/// Fallible version of [`project`] for validation paths.
pub fn try_project<T: Copy>(values: &[T], l1: &[Label], l2: &[Label]) -> Option<Vec<T>> {
    if values.len() != l2.len() {
        return None;
    }
    l1.iter()
        .map(|a| l2.iter().position(|b| b == a).map(|j| values[j]))
        .collect()
}

/// The paper's `⊙` operator: concatenate two label lists, removing
/// duplicates (keeping first occurrence) — the schema of a natural join.
/// Duplicates within `l1` itself are removed too, so
/// `concat_dedup(l_XY, [])` yields the unique-label list.
pub fn concat_dedup(l1: &[Label], l2: &[Label]) -> LabelList {
    let mut out: LabelList = Vec::with_capacity(l1.len() + l2.len());
    for &l in l1.iter().chain(l2) {
        if !out.contains(&l) {
            out.push(l);
        }
    }
    out
}

/// Plain concatenation `l_XY` (duplicates kept).
pub fn concat(l1: &[Label], l2: &[Label]) -> LabelList {
    let mut out = l1.to_vec();
    out.extend_from_slice(l2);
    out
}

/// Labels of `l1` not present in `l2` (order preserved): e.g. `l_agg` is
/// `unique(l_XY) \ l_Z`.
pub fn difference(l1: &[Label], l2: &[Label]) -> LabelList {
    l1.iter().filter(|l| !l2.contains(l)).copied().collect()
}

/// True if the list has no repeated label.
pub fn all_distinct(l: &[Label]) -> bool {
    for i in 0..l.len() {
        for j in (i + 1)..l.len() {
            if l[i] == l[j] {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_stable() {
        let a = Label::new("i");
        let b = Label::new("i");
        let c = Label::new("j");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.name(), "i");
    }

    #[test]
    fn labels_parses_sep() {
        assert_eq!(labels("i j k"), labels("i,j,k"));
        assert_eq!(labels("  i  "), vec![Label::new("i")]);
    }

    #[test]
    fn project_matches_paper_example() {
        // b = [2,3,4], l1 = [k,i], l2 = [i,j,k] => [4,2]
        let b = [2usize, 3, 4];
        let l1 = labels("k i");
        let l2 = labels("i j k");
        assert_eq!(project(&b, &l1, &l2), vec![4, 2]);
    }

    #[test]
    fn project_uses_first_occurrence() {
        // b_XY over l_XY with repeated labels: first occurrence is taken.
        let bxy = [10usize, 100, 20, 100, 20, 2000];
        let lxy = labels("i j b j b k");
        let lagg = labels("b j");
        assert_eq!(project(&bxy, &lagg, &lxy), vec![20, 100]);
    }

    #[test]
    fn try_project_missing_label() {
        let b = [1usize, 2];
        assert!(try_project(&b, &labels("z"), &labels("i j")).is_none());
    }

    #[test]
    fn concat_dedup_natural_join_schema() {
        let lx = labels("i j");
        let ly = labels("j k");
        assert_eq!(concat_dedup(&lx, &ly), labels("i j k"));
    }

    #[test]
    fn difference_gives_agg_labels() {
        let lxy = labels("i j j k");
        let lz = labels("i k");
        let uniq = concat_dedup(&lxy, &[]);
        assert_eq!(difference(&uniq, &lz), labels("j"));
    }

    #[test]
    fn distinctness() {
        assert!(all_distinct(&labels("i j k")));
        assert!(!all_distinct(&labels("i j i")));
    }
}

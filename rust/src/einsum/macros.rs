//! EinSum "macros": reusable sub-graph builders for the constructions the
//! paper spells out in Section 3 — numerically-stable softmax, the
//! attention mechanism, and multi-headed attention — plus small helpers
//! (linear layers) shared by the model builders in [`crate::models`].

use super::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use super::graph::{EinGraph, VertexId};
use super::label::{difference, Label, LabelList};
use crate::error::{Error, Result};

/// Numerically-stable softmax over the *last* rank of `x`, batched across
/// the leading ranks — exactly the paper's four-EinSum construction:
///
/// ```text
///   C_i   <- max_j X_ij
///   E_ij  <- e^(X_ij - C_i)     (SubExp join)
///   S_i   <- sum_j E_ij
///   Y_ij  <- E_ij / S_i
/// ```
pub fn softmax(g: &mut EinGraph, name: &str, x: VertexId, lx: &LabelList) -> Result<VertexId> {
    let rank = g.vertex(x).bound.len();
    if lx.len() != rank {
        return Err(Error::InvalidEinsum(format!(
            "softmax labels {lx:?} do not match rank {rank}"
        )));
    }
    if rank < 1 {
        return Err(Error::InvalidEinsum("softmax needs rank >= 1".into()));
    }
    let batch: LabelList = lx[..rank - 1].to_vec();
    let c = g.add(
        &format!("{name}.max"),
        EinSum::reduce(lx.clone(), batch.clone(), AggOp::Max),
        vec![x],
    )?;
    let e = g.add(
        &format!("{name}.exp"),
        EinSum::Binary {
            lx: lx.clone(),
            ly: batch.clone(),
            lz: lx.clone(),
            join: JoinOp::SubExp,
            agg: AggOp::Sum,
        },
        vec![x, c],
    )?;
    let s = g.add(
        &format!("{name}.sum"),
        EinSum::reduce(lx.clone(), batch.clone(), AggOp::Sum),
        vec![e],
    )?;
    g.add(
        &format!("{name}.norm"),
        EinSum::Binary {
            lx: lx.clone(),
            ly: batch,
            lz: lx.clone(),
            join: JoinOp::Div,
            agg: AggOp::Sum,
        },
        vec![e, s],
    )
}

/// Single-head attention `softmax(Q K^T / sqrt(d_k)) V` (paper Section 3):
///
/// ```text
///   T1_ik <- sum_j Q_ij K_kj          T2 <- T1 / sqrt(d_k)
///   T3    <- softmax(T2)              Y_ik <- sum_j T3_ij V_jk
/// ```
///
/// `q`, `k`, `v` are rank-2 with bounds `[s, d]`, `[s', d]`, `[s', d]`.
pub fn attention(
    g: &mut EinGraph,
    name: &str,
    q: VertexId,
    k: VertexId,
    v: VertexId,
) -> Result<VertexId> {
    let dk = *g
        .vertex(k)
        .bound
        .last()
        .ok_or_else(|| Error::InvalidEinsum("attention: K must be rank-2".into()))?;
    let (i, j, kk) = (Label::new("i"), Label::new("j"), Label::new("k"));
    let t1 = g.add(
        &format!("{name}.qk"),
        EinSum::contraction(vec![i, j], vec![kk, j], vec![i, kk]),
        vec![q, k],
    )?;
    let t2 = g.add(
        &format!("{name}.scale"),
        EinSum::map(vec![i, kk], UnaryOp::Scale(1.0 / (dk as f32).sqrt())),
        vec![t1],
    )?;
    let t3 = softmax(g, &format!("{name}.softmax"), t2, &vec![i, kk])?;
    g.add(
        &format!("{name}.av"),
        EinSum::contraction(vec![i, j], vec![j, kk], vec![i, kk]),
        vec![t3, v],
    )
}

/// Multi-headed attention, exactly the paper's EinSum formulation with
/// labels `s` (sequence), `s'`, `h` (head), `a` (attribute/model dim),
/// `d` (per-head dim), optionally batched with a leading `b` label:
///
/// ```text
///   QH_shd <- sum_a Q_sa WQ_ahd      (same for K, V)
///   T1_hss' <- sum_d QH_shd KH_s'hd      T2 <- T1 / sqrt(d_k)
///   T3 <- softmax(T2)                    O_shd <- sum_s' T3_hss' VH_s'hd
///   Y_sa <- sum_{h,d} O_shd WO_ahd
/// ```
///
/// Returns the output projection vertex. `batched=true` adds a leading `b`
/// dimension to the activations (weights are shared), which is the form
/// used for LLaMA first-token inference with batch > 1.
#[allow(clippy::too_many_arguments)]
pub fn multihead_attention(
    g: &mut EinGraph,
    name: &str,
    q: VertexId,
    k: VertexId,
    v: VertexId,
    wq: VertexId,
    wk: VertexId,
    wv: VertexId,
    wo: VertexId,
    batched: bool,
) -> Result<VertexId> {
    let b = Label::new("b");
    let s = Label::new("s");
    let s2 = Label::new("s'");
    let h = Label::new("h");
    let d = Label::new("d");
    let a = Label::new("a");
    let with_b = |mut l: LabelList| -> LabelList {
        if batched {
            let mut out = vec![b];
            out.append(&mut l);
            out
        } else {
            l
        }
    };
    // d_k = per-head dimension = last dim of WK [a, h, d]
    let dk = *g.vertex(wk).bound.last().unwrap() as f32;

    let proj = |g: &mut EinGraph, nm: &str, x: VertexId, w: VertexId| -> Result<VertexId> {
        // QH_(b)shd <- sum_a Q_(b)sa x WQ_ahd
        g.add(
            nm,
            EinSum::contraction(with_b(vec![s, a]), vec![a, h, d], with_b(vec![s, h, d])),
            vec![x, w],
        )
    };
    let qh = proj(g, &format!("{name}.qproj"), q, wq)?;
    let kh = proj(g, &format!("{name}.kproj"), k, wk)?;
    let vh = proj(g, &format!("{name}.vproj"), v, wv)?;

    // scores: T1_(b)hss' <- sum_d QH_(b)shd x KH_(b)s'hd
    // (the s' side reuses the same label list with s replaced by s')
    let kh_labels = with_b(vec![s2, h, d]);
    let t1 = g.add(
        &format!("{name}.scores"),
        EinSum::contraction(with_b(vec![s, h, d]), kh_labels, with_b(vec![h, s, s2])),
        vec![qh, kh],
    )?;
    let t2 = g.add(
        &format!("{name}.scale"),
        EinSum::map(with_b(vec![h, s, s2]), UnaryOp::Scale(1.0 / dk.sqrt())),
        vec![t1],
    )?;
    let t3 = softmax(g, &format!("{name}.softmax"), t2, &with_b(vec![h, s, s2]))?;
    // O_(b)shd <- sum_s' T3_(b)hss' x VH_(b)s'hd
    let o = g.add(
        &format!("{name}.attnv"),
        EinSum::contraction(
            with_b(vec![h, s, s2]),
            with_b(vec![s2, h, d]),
            with_b(vec![s, h, d]),
        ),
        vec![t3, vh],
    )?;
    // Y_(b)sa <- sum_{h,d} O_(b)shd x WO_ahd  (WO is rank-3 as in the paper)
    g.add(
        &format!("{name}.oproj"),
        EinSum::contraction(with_b(vec![s, h, d]), vec![a, h, d], with_b(vec![s, a])),
        vec![o, wo],
    )
}

/// Dense layer `Y[.., n] <- sum_f X[.., f] W[f, n]` with labels supplied by
/// the caller; optionally followed by a unary activation.
pub fn linear(
    g: &mut EinGraph,
    name: &str,
    x: VertexId,
    w: VertexId,
    lx: &LabelList,
    f: Label,
    n: Label,
    activation: Option<UnaryOp>,
) -> Result<VertexId> {
    let lz: LabelList = lx
        .iter()
        .map(|&l| if l == f { n } else { l })
        .collect();
    let mut out = g.add(
        name,
        EinSum::contraction(lx.clone(), vec![f, n], lz.clone()),
        vec![x, w],
    )?;
    if let Some(act) = activation {
        out = g.add(&format!("{name}.act"), EinSum::map(lz, act), vec![out])?;
    }
    Ok(out)
}

/// RMSNorm-style normalization used by LLaMA blocks, expressed in EinSum:
///
/// ```text
///   SQ = X^2 ; MS_s = (1/dim) sum_a SQ_sa ; R = rsqrt(MS) ;
///   XN_sa = X_sa * R_s ; Y_sa = XN_sa * G_a
/// ```
pub fn rmsnorm(
    g: &mut EinGraph,
    name: &str,
    x: VertexId,
    gain: VertexId,
    lx: &LabelList,
) -> Result<VertexId> {
    let rank = lx.len();
    let batch: LabelList = lx[..rank - 1].to_vec();
    let feat = lx[rank - 1];
    let dim = *g.vertex(x).bound.last().unwrap() as f32;
    let sq = g.add(
        &format!("{name}.sq"),
        EinSum::map(lx.clone(), UnaryOp::Square),
        vec![x],
    )?;
    let ssum = g.add(
        &format!("{name}.ssum"),
        EinSum::reduce(lx.clone(), batch.clone(), AggOp::Sum),
        vec![sq],
    )?;
    let ms = g.add(
        &format!("{name}.mean"),
        EinSum::map(batch.clone(), UnaryOp::Scale(1.0 / dim)),
        vec![ssum],
    )?;
    let r = g.add(
        &format!("{name}.rsqrt"),
        EinSum::map(batch.clone(), UnaryOp::Rsqrt),
        vec![ms],
    )?;
    let xn = g.add(
        &format!("{name}.apply"),
        EinSum::Binary {
            lx: lx.clone(),
            ly: batch,
            lz: lx.clone(),
            join: JoinOp::Mul,
            agg: AggOp::Sum,
        },
        vec![x, r],
    )?;
    g.add(
        &format!("{name}.gain"),
        EinSum::Binary {
            lx: lx.clone(),
            ly: vec![feat],
            lz: lx.clone(),
            join: JoinOp::Mul,
            agg: AggOp::Sum,
        },
        vec![xn, gain],
    )
}

/// Labels `l_agg` that a softmax over `lx` aggregates (the last label).
pub fn softmax_agg_labels(lx: &LabelList) -> LabelList {
    difference(lx, &lx[..lx.len() - 1].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::label::labels;

    #[test]
    fn softmax_shapes() {
        let mut g = EinGraph::new();
        let x = g.input("X", vec![4, 8]);
        let y = softmax(&mut g, "sm", x, &labels("i j")).unwrap();
        assert_eq!(g.vertex(y).bound, vec![4, 8]);
        g.validate().unwrap();
        // 4 EinSum vertices added
        assert_eq!(g.len(), 5);
    }

    #[test]
    fn softmax_rank3_batched() {
        let mut g = EinGraph::new();
        let x = g.input("X", vec![2, 4, 8]);
        let y = softmax(&mut g, "sm", x, &labels("h s t")).unwrap();
        assert_eq!(g.vertex(y).bound, vec![2, 4, 8]);
    }

    #[test]
    fn attention_shapes() {
        let mut g = EinGraph::new();
        let q = g.input("Q", vec![16, 8]);
        let k = g.input("K", vec![16, 8]);
        let v = g.input("V", vec![16, 8]);
        let y = attention(&mut g, "attn", q, k, v).unwrap();
        assert_eq!(g.vertex(y).bound, vec![16, 8]);
        g.validate().unwrap();
    }

    #[test]
    fn mha_shapes_match_paper() {
        // Q,K,V: [s, a]; W{Q,K,V}: [a, h, d]; WO: [a, h, d]; out [s, a]
        let (s, a, h, d) = (16, 32, 4, 8);
        let mut g = EinGraph::new();
        let q = g.input("Q", vec![s, a]);
        let k = g.input("K", vec![s, a]);
        let v = g.input("V", vec![s, a]);
        let wq = g.input("WQ", vec![a, h, d]);
        let wk = g.input("WK", vec![a, h, d]);
        let wv = g.input("WV", vec![a, h, d]);
        let wo = g.input("WO", vec![a, h, d]);
        let y = multihead_attention(&mut g, "mha", q, k, v, wq, wk, wv, wo, false).unwrap();
        assert_eq!(g.vertex(y).bound, vec![s, a]);
        g.validate().unwrap();
        // scores vertex has bound [h, s, s]
        let scores = g.by_name("mha.scores").unwrap();
        assert_eq!(g.vertex(scores).bound, vec![h, s, s]);
    }

    #[test]
    fn mha_batched() {
        let (b, s, a, h, d) = (2, 8, 16, 2, 8);
        let mut g = EinGraph::new();
        let q = g.input("Q", vec![b, s, a]);
        let k = g.input("K", vec![b, s, a]);
        let v = g.input("V", vec![b, s, a]);
        let wq = g.input("WQ", vec![a, h, d]);
        let wk = g.input("WK", vec![a, h, d]);
        let wv = g.input("WV", vec![a, h, d]);
        let wo = g.input("WO", vec![a, h, d]);
        let y = multihead_attention(&mut g, "mha", q, k, v, wq, wk, wv, wo, true).unwrap();
        assert_eq!(g.vertex(y).bound, vec![b, s, a]);
        g.validate().unwrap();
    }

    #[test]
    fn linear_with_activation() {
        let mut g = EinGraph::new();
        let x = g.input("X", vec![4, 8]);
        let w = g.input("W", vec![8, 16]);
        let (bl, f, n) = (Label::new("bb"), Label::new("f"), Label::new("n"));
        let y = linear(&mut g, "fc", x, w, &vec![bl, f], f, n, Some(UnaryOp::Relu)).unwrap();
        assert_eq!(g.vertex(y).bound, vec![4, 16]);
    }

    #[test]
    fn rmsnorm_shapes() {
        let mut g = EinGraph::new();
        let x = g.input("X", vec![8, 32]);
        let gain = g.input("G", vec![32]);
        let y = rmsnorm(&mut g, "rms", x, gain, &labels("s a")).unwrap();
        assert_eq!(g.vertex(y).bound, vec![8, 32]);
        g.validate().unwrap();
    }
}

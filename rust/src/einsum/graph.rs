//! EinGraphs — DAGs of EinSum expressions (paper Section 5).
//!
//! Each vertex is the triple `(bound, EinSum, inputs)`. `inputs` is ordered
//! (EinSum need not be commutative) and empty iff the vertex is an `Input`.
//! Bounds of non-input vertices are derived from the EinSum labels and the
//! input bounds at insertion time, so a constructed graph is always
//! shape-consistent.

use super::expr::EinSum;
use super::label::Label;
use crate::error::{Error, Result};
use std::collections::HashMap;

/// Index of a vertex within its [`EinGraph`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VertexId(pub usize);

impl std::fmt::Display for VertexId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "v{}", self.0)
    }
}

/// A vertex of an EinGraph: `(bound, EinSum, inputs)` plus a debug name.
#[derive(Clone, Debug)]
pub struct Vertex {
    pub id: VertexId,
    pub name: String,
    /// Output bound vector `b` of this vertex.
    pub bound: Vec<usize>,
    pub op: EinSum,
    /// Ordered input vertices (empty iff `op == EinSum::Input`).
    pub inputs: Vec<VertexId>,
}

/// A directed acyclic graph of EinSum expressions.
#[derive(Clone, Debug, Default)]
pub struct EinGraph {
    vertices: Vec<Vertex>,
    by_name: HashMap<String, VertexId>,
}

impl EinGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an input (leaf) vertex with an explicit bound.
    pub fn input(&mut self, name: &str, bound: Vec<usize>) -> VertexId {
        self.push(name, bound, EinSum::Input, vec![])
    }

    /// Add a computation vertex; the bound is inferred from the EinSum and
    /// the bounds of `inputs`. Accepts anything iterable over vertex ids —
    /// `vec![a, b]`, `[a, b]`, or `&[a, b]` — so call sites need not
    /// allocate.
    pub fn add<I>(&mut self, name: &str, op: EinSum, inputs: I) -> Result<VertexId>
    where
        I: IntoIterator,
        I::Item: std::borrow::Borrow<VertexId>,
    {
        let inputs: Vec<VertexId> = inputs
            .into_iter()
            .map(|v| *std::borrow::Borrow::borrow(&v))
            .collect();
        if op.arity() != inputs.len() {
            return Err(Error::InvalidGraph(format!(
                "vertex {name}: op arity {} but {} inputs given",
                op.arity(),
                inputs.len()
            )));
        }
        for &i in &inputs {
            if i.0 >= self.vertices.len() {
                return Err(Error::InvalidGraph(format!(
                    "vertex {name}: dangling input {i}"
                )));
            }
        }
        let in_bounds: Vec<&[usize]> = inputs
            .iter()
            .map(|&i| self.vertices[i.0].bound.as_slice())
            .collect();
        let bound = op.infer_bound(&in_bounds)?;
        Ok(self.push(name, bound, op, inputs))
    }

    fn push(&mut self, name: &str, bound: Vec<usize>, op: EinSum, inputs: Vec<VertexId>) -> VertexId {
        let id = VertexId(self.vertices.len());
        let mut name = name.to_string();
        if self.by_name.contains_key(&name) {
            name = format!("{name}#{}", id.0);
        }
        self.by_name.insert(name.clone(), id);
        self.vertices.push(Vertex {
            id,
            name,
            bound,
            op,
            inputs,
        });
        id
    }

    pub fn vertex(&self, id: VertexId) -> &Vertex {
        &self.vertices[id.0]
    }

    pub fn by_name(&self, name: &str) -> Option<VertexId> {
        self.by_name.get(name).copied()
    }

    pub fn len(&self) -> usize {
        self.vertices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vertices.is_empty()
    }

    pub fn vertices(&self) -> &[Vertex] {
        &self.vertices
    }

    /// Input (leaf) vertices.
    pub fn inputs(&self) -> Vec<VertexId> {
        self.vertices
            .iter()
            .filter(|v| matches!(v.op, EinSum::Input))
            .map(|v| v.id)
            .collect()
    }

    /// Vertices with no consumers (graph outputs).
    pub fn outputs(&self) -> Vec<VertexId> {
        let mut consumed = vec![false; self.vertices.len()];
        for v in &self.vertices {
            for &i in &v.inputs {
                consumed[i.0] = true;
            }
        }
        self.vertices
            .iter()
            .filter(|v| !consumed[v.id.0])
            .map(|v| v.id)
            .collect()
    }

    /// consumers[v] = vertices that read v's output.
    pub fn consumers(&self) -> Vec<Vec<VertexId>> {
        let mut c: Vec<Vec<VertexId>> = vec![vec![]; self.vertices.len()];
        for v in &self.vertices {
            for &i in &v.inputs {
                c[i.0].push(v.id);
            }
        }
        c
    }

    /// True if no non-input vertex output is consumed more than once —
    /// the precondition for the exact DP of Section 8.2.
    pub fn is_tree_like(&self) -> bool {
        self.consumers()
            .iter()
            .zip(&self.vertices)
            .all(|(c, v)| c.len() <= 1 || matches!(v.op, EinSum::Input))
    }

    /// Vertices in topological order (inputs first). Construction order is
    /// already topological (inputs must exist before use), so this is the
    /// identity — kept as an explicit method for clarity and validation.
    pub fn topo_order(&self) -> Vec<VertexId> {
        (0..self.vertices.len()).map(VertexId).collect()
    }

    /// Validate structural invariants (acyclicity is by construction; this
    /// re-checks bounds and arities, useful after deserialization).
    pub fn validate(&self) -> Result<()> {
        for v in &self.vertices {
            if v.op.arity() != v.inputs.len() {
                return Err(Error::InvalidGraph(format!(
                    "{}: arity mismatch",
                    v.name
                )));
            }
            for &i in &v.inputs {
                if i.0 >= v.id.0 {
                    return Err(Error::InvalidGraph(format!(
                        "{}: input {} does not precede vertex (cycle or dangling)",
                        v.name, i
                    )));
                }
            }
            if !matches!(v.op, EinSum::Input) {
                let in_bounds: Vec<&[usize]> = v
                    .inputs
                    .iter()
                    .map(|&i| self.vertices[i.0].bound.as_slice())
                    .collect();
                let b = v.op.infer_bound(&in_bounds)?;
                if b != v.bound {
                    return Err(Error::InvalidGraph(format!(
                        "{}: stored bound {:?} != derived {:?}",
                        v.name, v.bound, b
                    )));
                }
            }
        }
        Ok(())
    }

    /// Batched twin of this graph: a fresh batch label of bound `batch`
    /// is prepended to every vertex — input bounds become `[batch] ++
    /// bound` and every op's label lists gain the batch label up front
    /// (see [`EinSum::batched`]). Vertex ids, names, and wiring are
    /// preserved exactly, so ids translate 1:1 between a graph and its
    /// twin.
    ///
    /// This is the stacking primitive behind dynamic batching (the
    /// `serve` subsystem): `batch` independent runs of `self` equal one
    /// run of the twin with inputs stacked along the leading dim. Because
    /// the batch label is kept in every operand *and* output, batch
    /// entries never mix, and each op's kernel dispatch path matches the
    /// solo op's — which is what makes the twin's slices bitwise-equal to
    /// solo runs.
    pub fn batched(&self, batch: usize) -> Result<EinGraph> {
        if batch == 0 {
            return Err(Error::InvalidGraph(
                "batched: batch size must be >= 1".into(),
            ));
        }
        // A fresh label: one that no vertex of this graph mentions.
        let used: std::collections::HashSet<Label> = self
            .vertices
            .iter()
            .flat_map(|v| {
                let mut ls: Vec<Label> = v
                    .op
                    .operand_labels()
                    .into_iter()
                    .flatten()
                    .copied()
                    .collect();
                ls.extend(v.op.lz().into_iter().flatten().copied());
                ls
            })
            .collect();
        let mut b = Label::new("__batch");
        let mut salt = 0usize;
        while used.contains(&b) {
            b = Label::new(&format!("__batch{salt}"));
            salt += 1;
        }
        let mut out = EinGraph::new();
        for v in &self.vertices {
            let id = match &v.op {
                EinSum::Input => {
                    let mut bound = Vec::with_capacity(v.bound.len() + 1);
                    bound.push(batch);
                    bound.extend_from_slice(&v.bound);
                    out.input(&v.name, bound)
                }
                op => out.add(&v.name, op.batched(b), v.inputs.iter().copied())?,
            };
            debug_assert_eq!(id, v.id, "batched twin must preserve vertex ids");
        }
        Ok(out)
    }

    /// Total flops of the computation (hardware-independent; identical for
    /// every decomposition, per the paper's costing premise).
    pub fn total_flops(&self) -> f64 {
        self.vertices
            .iter()
            .map(|v| {
                let in_bounds: Vec<&[usize]> = v
                    .inputs
                    .iter()
                    .map(|&i| self.vertices[i.0].bound.as_slice())
                    .collect();
                v.op.flops(&in_bounds).unwrap_or(0.0)
            })
            .sum()
    }

    /// Decompose the graph into node-disjoint paths, longest first — the
    /// linearization of Section 8.4 (Figure 6). Only non-input vertices are
    /// placed on paths; each path is returned in topological order.
    pub fn linear_paths(&self) -> Vec<Vec<VertexId>> {
        let n = self.vertices.len();
        let mut assigned = vec![false; n];
        // inputs never sit on a path (their cost is zero, M[v,d]=0)
        for v in &self.vertices {
            if matches!(v.op, EinSum::Input) {
                assigned[v.id.0] = true;
            }
        }
        let consumers = self.consumers();
        let mut paths = Vec::new();
        loop {
            // longest[v]: length of the longest path starting at v through
            // unassigned vertices, following producer->consumer edges.
            let mut longest = vec![0usize; n];
            let mut next: Vec<Option<VertexId>> = vec![None; n];
            for v in (0..n).rev() {
                if assigned[v] {
                    continue;
                }
                longest[v] = 1;
                for &c in &consumers[v] {
                    if !assigned[c.0] && longest[c.0] + 1 > longest[v] {
                        longest[v] = longest[c.0] + 1;
                        next[v] = Some(c);
                    }
                }
            }
            let Some(start) = (0..n)
                .filter(|&v| !assigned[v])
                .max_by_key(|&v| longest[v])
            else {
                break;
            };
            if longest[start] == 0 {
                break;
            }
            let mut path = Vec::new();
            let mut cur = Some(VertexId(start));
            while let Some(v) = cur {
                path.push(v);
                assigned[v.0] = true;
                cur = next[v.0];
            }
            paths.push(path);
        }
        paths
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::expr::{AggOp, JoinOp, UnaryOp};
    use crate::einsum::label::labels;

    fn chain_graph() -> (EinGraph, VertexId) {
        // Z = (A x B) + (C x (D x E)) — the paper's Experiment 1 chain.
        let mut g = EinGraph::new();
        let s = 8;
        let a = g.input("A", vec![s, s]);
        let b = g.input("B", vec![s, s]);
        let c = g.input("C", vec![s, s]);
        let d = g.input("D", vec![s, s]);
        let e = g.input("E", vec![s, s]);
        let ab = g
            .add(
                "AB",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let de = g
            .add(
                "DE",
                EinSum::contraction(labels("j k"), labels("k m"), labels("j m")),
                vec![d, e],
            )
            .unwrap();
        let cde = g
            .add(
                "CDE",
                EinSum::contraction(labels("i j"), labels("j m"), labels("i m")),
                vec![c, de],
            )
            .unwrap();
        let z = g
            .add(
                "Z",
                EinSum::elementwise(labels("i k"), labels("i k"), JoinOp::Add),
                vec![ab, cde],
            )
            .unwrap();
        (g, z)
    }

    #[test]
    fn build_and_validate() {
        let (g, z) = chain_graph();
        g.validate().unwrap();
        assert_eq!(g.vertex(z).bound, vec![8, 8]);
        assert_eq!(g.outputs(), vec![z]);
        assert_eq!(g.inputs().len(), 5);
        assert!(g.is_tree_like());
    }

    #[test]
    fn elementwise_add_requires_matching_labels() {
        // Z = AB + CDE: 'i k' vs 'i m' would be a label mismatch caught by
        // bound inference only if bounds differ; with labels shared the
        // output dedups correctly. Check bound inference catches a real
        // mismatch:
        let mut g = EinGraph::new();
        let a = g.input("A", vec![4, 4]);
        let b = g.input("B", vec![4, 5]);
        let r = g.add(
            "bad",
            EinSum::elementwise(labels("i j"), labels("i j"), JoinOp::Add),
            vec![a, b],
        );
        assert!(r.is_err());
    }

    #[test]
    fn arity_checked() {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![4, 4]);
        assert!(g
            .add(
                "bad",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a],
            )
            .is_err());
    }

    #[test]
    fn add_accepts_slices_and_arrays() {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![4, 4]);
        let b = g.input("B", vec![4, 4]);
        let op = || EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        let z1 = g.add("Z1", op(), &[a, b]).unwrap();
        let z2 = g.add("Z2", op(), [a, b]).unwrap();
        let z3 = g.add("Z3", op(), vec![a, b]).unwrap();
        assert_eq!(g.vertex(z1).inputs, g.vertex(z2).inputs);
        assert_eq!(g.vertex(z2).inputs, g.vertex(z3).inputs);
    }

    #[test]
    fn duplicate_names_are_uniquified() {
        let mut g = EinGraph::new();
        let a = g.input("X", vec![2]);
        let b = g.input("X", vec![3]);
        assert_ne!(g.vertex(a).name, g.vertex(b).name);
    }

    #[test]
    fn non_tree_detected() {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![4, 4]);
        let sq = g
            .add("sq", EinSum::map(labels("i j"), UnaryOp::Square), vec![a])
            .unwrap();
        // two consumers of sq
        g.add("r1", EinSum::reduce(labels("i j"), labels("i"), AggOp::Sum), vec![sq])
            .unwrap();
        g.add("r2", EinSum::reduce(labels("i j"), labels("j"), AggOp::Sum), vec![sq])
            .unwrap();
        assert!(!g.is_tree_like());
    }

    #[test]
    fn linear_paths_cover_all_non_inputs() {
        let (g, _) = chain_graph();
        let paths = g.linear_paths();
        let covered: usize = paths.iter().map(|p| p.len()).sum();
        assert_eq!(covered, 4); // AB, DE, CDE, Z
        // longest path first: DE -> CDE -> Z (length 3)
        assert_eq!(paths[0].len(), 3);
        // paths are node-disjoint
        let mut seen = std::collections::HashSet::new();
        for p in &paths {
            for v in p {
                assert!(seen.insert(*v));
            }
        }
    }

    #[test]
    fn total_flops_positive() {
        let (g, _) = chain_graph();
        assert!(g.total_flops() > 0.0);
    }

    #[test]
    fn batched_twin_preserves_structure() {
        let (g, z) = chain_graph();
        let bg = g.batched(4).unwrap();
        bg.validate().unwrap();
        assert_eq!(bg.len(), g.len());
        for v in g.vertices() {
            let bv = bg.vertex(v.id);
            // ids, names, wiring preserved; bounds gain a leading 4
            assert_eq!(bv.id, v.id);
            assert_eq!(bv.name, v.name);
            assert_eq!(bv.inputs, v.inputs);
            let mut want = vec![4];
            want.extend_from_slice(&v.bound);
            assert_eq!(bv.bound, want);
            // batch label is the *first* unique label of every op, so a
            // solo partitioning vector extends by prepending one entry
            if !matches!(v.op, EinSum::Input) {
                let solo = v.op.unique_labels();
                let twin = bv.op.unique_labels();
                assert_eq!(twin.len(), solo.len() + 1);
                assert_eq!(&twin[1..], &solo[..]);
                assert!(!solo.contains(&twin[0]), "batch label must be fresh");
            }
        }
        assert_eq!(bg.vertex(z).bound, vec![4, 8, 8]);
        assert_eq!(bg.outputs(), vec![z]);
    }

    #[test]
    fn batched_picks_fresh_label_on_collision() {
        // a graph that already uses the label "__batch"
        let mut g = EinGraph::new();
        let a = g.input("A", vec![3, 5]);
        g.add(
            "R",
            EinSum::reduce(labels("__batch j"), labels("__batch"), AggOp::Sum),
            vec![a],
        )
        .unwrap();
        let bg = g.batched(2).unwrap();
        bg.validate().unwrap();
        let r = bg.vertex(VertexId(1));
        let uniq = r.op.unique_labels();
        assert_ne!(uniq[0], Label::new("__batch"));
        assert_eq!(r.bound, vec![2, 3]);
    }

    #[test]
    fn batched_rejects_zero() {
        let (g, _) = chain_graph();
        assert!(g.batched(0).is_err());
    }
}

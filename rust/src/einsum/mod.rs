//! The EinSum language: labels, expressions, graphs, parser, the lazy
//! [`Expr`] frontend, canonical graph signatures ([`canon`]), and model
//! macros (softmax, attention, ...). This is the paper's *programming
//! abstraction* (Section 3): a fully declarative specification of tensor
//! computations from which the system derives parallel decompositions.

pub mod autodiff;
pub mod canon;
pub mod expr;
pub mod graph;
pub mod label;
pub mod lazy;
pub mod macros;
pub mod parser;

pub use canon::{canonicalize, Canon, CanonSignature};
pub use expr::{AggOp, EinSum, JoinOp, UnaryOp};
pub use graph::{EinGraph, Vertex, VertexId};
pub use label::{labels, Label, LabelList};
pub use lazy::Expr;

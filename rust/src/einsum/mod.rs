//! The EinSum language: labels, expressions, graphs, parser, and model
//! macros (softmax, attention, ...). This is the paper's *programming
//! abstraction* (Section 3): a fully declarative specification of tensor
//! computations from which the system derives parallel decompositions.

pub mod autodiff;
pub mod expr;
pub mod graph;
pub mod label;
pub mod macros;
pub mod parser;

pub use expr::{AggOp, EinSum, JoinOp, UnaryOp};
pub use graph::{EinGraph, Vertex, VertexId};
pub use label::{labels, Label, LabelList};

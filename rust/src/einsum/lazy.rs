//! Lazy expression frontend: build EinGraphs by chaining methods on
//! [`Expr`] handles instead of the three-step
//! `EinGraph::new` / `input` / `add(EinSum::contraction(labels(...)))`
//! ceremony.
//!
//! Expressions are created by
//! [`Session::input`](crate::coordinator::session::Session::input) and
//! grow a shared staging [`EinGraph`] under the hood; einsum specs are
//! parsed with the existing textual frontend
//! ([`crate::einsum::parser::einsum_from_spec`]), so everything the
//! `"ij,jk->ik"` / `"b i j, b j k -> b i k"` notation supports is
//! available here. The finished expression compiles through
//! [`Session::compile_expr`](crate::coordinator::session::Session::compile_expr),
//! which snapshots the staged graph into an
//! [`Executable`](crate::coordinator::session::Executable).
//!
//! Labels remain *local to each vertex* (producer→consumer axis
//! correspondence is positional), so specs on different expressions do
//! not need to share letters.
//!
//! ```
//! use eindecomp::prelude::*;
//!
//! let session = Session::new(DriverConfig { workers: 2, p: 2, ..Default::default() })?;
//! let a = session.input("A", &[16, 16]);
//! let b = session.input("B", &[16, 16]);
//! let z = a.einsum("ij,jk->ik", &b)?.map(UnaryOp::Relu)?.reduce("ik->i", AggOp::Sum)?;
//! assert_eq!(z.shape(), vec![16]);
//! assert_eq!(z.graph().len(), 5); // A, B, einsum, map, reduce
//! # Ok::<(), eindecomp::Error>(())
//! ```

use super::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use super::graph::{EinGraph, VertexId};
use super::parser::{default_labels, einsum_from_spec, parse_spec};
use crate::error::{Error, Result};
use std::sync::{Arc, Mutex};

/// A lazily-built vertex handle: a node of a staging [`EinGraph`] shared
/// by every expression of the same program. Cloning is cheap (an `Arc`
/// bump); all combinators return fresh handles and leave `self` usable.
#[derive(Clone)]
pub struct Expr {
    graph: Arc<Mutex<EinGraph>>,
    id: VertexId,
}

impl Expr {
    /// Create an input expression in `graph` (crate-internal: the public
    /// entry is `Session::input`).
    pub(crate) fn input(graph: &Arc<Mutex<EinGraph>>, name: &str, shape: &[usize]) -> Expr {
        let id = graph.lock().unwrap().input(name, shape.to_vec());
        Expr {
            graph: Arc::clone(graph),
            id,
        }
    }

    /// The staging graph this expression belongs to (crate-internal).
    pub(crate) fn builder(&self) -> &Arc<Mutex<EinGraph>> {
        &self.graph
    }

    /// Vertex id of this expression — the key for input tensors and run
    /// outputs of the compiled program.
    pub fn id(&self) -> VertexId {
        self.id
    }

    /// Output bound (shape) of this expression.
    pub fn shape(&self) -> Vec<usize> {
        self.graph.lock().unwrap().vertex(self.id).bound.clone()
    }

    /// Snapshot of the program built so far, as a plain [`EinGraph`]
    /// (vertex ids of expressions are valid in the snapshot).
    pub fn graph(&self) -> EinGraph {
        self.graph.lock().unwrap().clone()
    }

    fn same_program(&self, other: &Expr) -> Result<()> {
        if Arc::ptr_eq(&self.graph, &other.graph) {
            Ok(())
        } else {
            Err(Error::InvalidGraph(
                "expressions belong to different programs (one was created after an earlier \
                 program was compiled); build each program from a fresh set of session inputs"
                    .into(),
            ))
        }
    }

    fn push(&self, name: &str, op: EinSum, inputs: &[VertexId]) -> Result<Expr> {
        let id = self.graph.lock().unwrap().add(name, op, inputs)?;
        Ok(Expr {
            graph: Arc::clone(&self.graph),
            id,
        })
    }

    /// Binary einsum with the classic `Mul`/`Sum` contraction semantics:
    /// `a.einsum("ij,jk->ik", &b)`. For other join/agg operators use
    /// [`einsum_ext`](Self::einsum_ext); for unary specs use
    /// [`reduce`](Self::reduce).
    pub fn einsum(&self, spec: &str, rhs: &Expr) -> Result<Expr> {
        self.einsum_ext(spec, rhs, JoinOp::Mul, AggOp::Sum)
    }

    /// Binary einsum with explicit join and aggregation operators (the
    /// paper's extended Einstein notation, Eq. 2) — e.g. `AbsDiff`/`Max`
    /// computes pairwise L∞ distances.
    pub fn einsum_ext(&self, spec: &str, rhs: &Expr, join: JoinOp, agg: AggOp) -> Result<Expr> {
        self.same_program(rhs)?;
        let e = einsum_from_spec(spec, agg, join)?;
        if e.arity() != 2 {
            return Err(Error::Parse(format!(
                "einsum spec {spec:?} has {} operand(s); use reduce() for unary specs",
                e.arity()
            )));
        }
        self.push(&format!("einsum({spec})"), e, &[self.id, rhs.id])
    }

    /// Shape-preserving elementwise map (`relu`, `exp`, `Scale(c)`, ...).
    pub fn map(&self, op: UnaryOp) -> Result<Expr> {
        let lx = default_labels(self.shape().len());
        self.push(&format!("map({op:?})"), EinSum::map(lx, op), &[self.id])
    }

    /// Unary einsum `"ij->i"`: aggregate out the dropped labels with
    /// `agg` (and/or transpose, when the output permutes the input).
    pub fn reduce(&self, spec: &str, agg: AggOp) -> Result<Expr> {
        let (ops, lz) = parse_spec(spec)?;
        if ops.len() != 1 {
            return Err(Error::Parse(format!(
                "reduce spec {spec:?} must be unary, like \"ij->i\""
            )));
        }
        self.push(
            &format!("reduce({spec})"),
            EinSum::reduce(ops[0].clone(), lz, agg),
            &[self.id],
        )
    }

    /// Elementwise binary op against a same-rank expression (labels are
    /// positional, so no spec is needed): `a.ew(JoinOp::Add, &b)`.
    pub fn ew(&self, join: JoinOp, rhs: &Expr) -> Result<Expr> {
        self.same_program(rhs)?;
        let lx = default_labels(self.shape().len());
        let ly = default_labels(rhs.shape().len());
        self.push(
            &format!("ew({join:?})"),
            EinSum::elementwise(lx, ly, join),
            &[self.id, rhs.id],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn program() -> (Expr, Expr) {
        let g = Arc::new(Mutex::new(EinGraph::new()));
        let a = Expr::input(&g, "A", &[8, 4]);
        let b = Expr::input(&g, "B", &[4, 8]);
        (a, b)
    }

    #[test]
    fn chained_build_matches_manual_graph() {
        let (a, b) = program();
        let z = a.einsum("ij,jk->ik", &b).unwrap();
        assert_eq!(z.shape(), vec![8, 8]);
        let r = z.map(UnaryOp::Relu).unwrap();
        let s = r.reduce("ij->j", AggOp::Max).unwrap();
        assert_eq!(s.shape(), vec![8]);
        let g = s.graph();
        g.validate().unwrap();
        assert_eq!(g.len(), 5);
        assert_eq!(g.outputs(), vec![s.id()]);
    }

    #[test]
    fn snapshot_supports_batched_twin() {
        // the serving batcher stacks lazily-built programs too: a
        // snapshot's batched twin must validate, keep vertex ids, and
        // prepend the batch bound everywhere
        let (a, b) = program();
        let z = a.einsum("ij,jk->ik", &b).unwrap().map(UnaryOp::Relu).unwrap();
        let g = z.graph();
        let bg = g.batched(3).unwrap();
        bg.validate().unwrap();
        assert_eq!(bg.vertex(z.id()).bound, vec![3, 8, 8]);
        assert_eq!(bg.vertex(a.id()).bound, vec![3, 8, 4]);
        assert_eq!(bg.outputs(), g.outputs());
        assert_eq!(bg.inputs(), g.inputs());
    }

    #[test]
    fn ew_and_ext_ops() {
        let (a, b) = program();
        let d = a.einsum_ext("ij,jk->ik", &b, JoinOp::AbsDiff, AggOp::Max).unwrap();
        let sum = d.ew(JoinOp::Add, &d).unwrap();
        assert_eq!(sum.shape(), vec![8, 8]);
    }

    #[test]
    fn unary_spec_through_einsum_rejected() {
        let (a, b) = program();
        assert!(a.einsum("ij->i", &b).is_err());
        assert!(a.reduce("ij,jk->ik", AggOp::Sum).is_err());
    }

    #[test]
    fn cross_program_mixing_rejected() {
        let (a, _) = program();
        let (_, b2) = program();
        assert!(a.einsum("ij,jk->ik", &b2).is_err());
    }

    #[test]
    fn bad_shapes_surface_as_errors() {
        let (a, b) = program();
        // inner dimensions disagree under this spec (4 vs 8)
        assert!(a.einsum("ij,kj->ik", &b).is_err());
    }
}

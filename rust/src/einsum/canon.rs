//! Canonical EinGraph signatures — the plan-cache key of the
//! compile-once / run-many [`crate::coordinator::session::Session`] API.
//!
//! Two EinGraphs that are *semantically identical programs* must map to
//! the same signature even when they differ syntactically:
//!
//! * **label renaming** — labels are local to a vertex (the correspondence
//!   between a producer's output axes and a consumer's operand axes is
//!   positional), so `"ij,jk->ik"` and `"ab,bc->ac"` are the same
//!   contraction. Each vertex's labels are renumbered by first occurrence
//!   across its operand lists, which preserves exactly the equality
//!   pattern the EinSum semantics depend on;
//! * **vertex renumbering** — any topological insertion order of the same
//!   DAG is the same program. Vertices are ordered by an iteratively
//!   refined structural key (Weisfeiler–Leman style: a vertex's key mixes
//!   its op/bound atom, its ordered operand keys, and its sorted
//!   (consumer-key, operand-position) pairs), so isomorphic graphs sort
//!   into the same canonical order regardless of how they were built.
//!
//! Shapes are part of the signature (the `bound` vector of every vertex),
//! so the same program at different sizes — which plans, lowers, and
//! places differently — never collides. Vertex *names* are deliberately
//! excluded.
//!
//! The signature itself is the exact, human-readable canonical listing
//! (not a hash), so equal signatures imply isomorphic graphs: a cache hit
//! can never hand back the plan of a different program. The refinement
//! keys are only used for ordering; a hash collision there can at worst
//! produce a spurious *miss*, never a false hit.
//!
//! ```
//! use eindecomp::einsum::canon::canonicalize;
//! use eindecomp::einsum::expr::EinSum;
//! use eindecomp::einsum::graph::EinGraph;
//! use eindecomp::einsum::label::labels;
//!
//! let mut g1 = EinGraph::new();
//! let a = g1.input("A", vec![8, 8]);
//! let b = g1.input("B", vec![8, 8]);
//! g1.add("Z", EinSum::contraction(labels("i j"), labels("j k"), labels("i k")), vec![a, b])?;
//!
//! // Same program, renamed labels and tensors.
//! let mut g2 = EinGraph::new();
//! let x = g2.input("X", vec![8, 8]);
//! let y = g2.input("Y", vec![8, 8]);
//! g2.add("W", EinSum::contraction(labels("p q"), labels("q r"), labels("p r")), vec![x, y])?;
//!
//! assert_eq!(canonicalize(&g1).signature, canonicalize(&g2).signature);
//! # Ok::<(), eindecomp::Error>(())
//! ```

use super::expr::{EinSum, UnaryOp};
use super::graph::{EinGraph, VertexId};
use super::label::{Label, LabelList};
use std::collections::HashMap;
use std::fmt::Write;

/// A canonical graph signature: equal signatures ⇔ the graphs are the same
/// program (isomorphic DAGs of identical ops at identical shapes, up to
/// label and vertex renaming). Cheap to hash and compare; used as the
/// plan-cache key.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CanonSignature {
    text: String,
}

impl CanonSignature {
    /// The full canonical listing (one `;`-terminated entry per vertex).
    pub fn text(&self) -> &str {
        &self.text
    }

    /// 64-bit digest of the listing — for logs and reports, not equality.
    pub fn digest(&self) -> u64 {
        fnv(self.text.as_bytes())
    }
}

impl std::fmt::Display for CanonSignature {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "sig:{:016x}", self.digest())
    }
}

/// Result of canonicalizing one graph: the signature plus the vertex
/// permutation, which lets a cache hit remap tensors between a presented
/// graph and the stored one (`order[canon_of[v.0]] == v`).
#[derive(Clone, Debug)]
pub struct Canon {
    pub signature: CanonSignature,
    /// `canon_of[vid.0]` = canonical position of vertex `vid`.
    pub canon_of: Vec<usize>,
    /// `order[i]` = the vertex at canonical position `i`.
    pub order: Vec<VertexId>,
}

impl Canon {
    /// The signature extended with every vertex's concrete label *names*
    /// (in canonical vertex order). Role-driven strategies (data-parallel,
    /// Megatron, sequence, attention-head) pick partitionings by label
    /// name via [`crate::decomp::baselines::LabelRoles`], so their plans
    /// are **not** invariant under renaming — sessions planning with such
    /// a strategy key their cache with this signature instead, trading
    /// rename-hits for correctness.
    pub fn named_signature(&self, g: &EinGraph) -> CanonSignature {
        let mut text = String::from(self.signature.text());
        text.push_str("|names:");
        for &vid in &self.order {
            let v = g.vertex(vid);
            for l in v.op.operand_labels() {
                for lab in l {
                    write!(text, "{lab},").unwrap();
                }
                text.push(';');
            }
            if let Some(lz) = v.op.lz() {
                for lab in lz {
                    write!(text, "{lab},").unwrap();
                }
            }
            text.push('/');
        }
        CanonSignature { text }
    }
}

/// FNV-1a over bytes (deterministic across runs; the crate is
/// dependency-free by design, so no external hashers).
fn fnv(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// SplitMix64 finalizer — scrambles a refinement key between rounds.
fn scramble(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Order-sensitive key combiner.
fn mix(h: u64, v: u64) -> u64 {
    scramble(h.wrapping_mul(0x0000_0100_0000_01b3).wrapping_add(v))
}

/// Renumber labels by first occurrence across the given lists, preserving
/// the equality pattern (`"i j" / "j k" -> [0,1] / [1,2]`).
fn renumber(lists: &[&LabelList]) -> Vec<Vec<usize>> {
    let mut map: HashMap<Label, usize> = HashMap::new();
    let mut out = Vec::with_capacity(lists.len());
    for l in lists {
        let mut v = Vec::with_capacity(l.len());
        for &lab in l.iter() {
            let next = map.len();
            v.push(*map.entry(lab).or_insert(next));
        }
        out.push(v);
    }
    out
}

/// Stable signature of a unary scalar op (constants by bit pattern, so
/// `Scale(0.5)` never aliases `Scale(0.25)` across float formattings).
fn unary_sig(op: &UnaryOp) -> String {
    match op {
        UnaryOp::Scale(c) => format!("Scale#{:08x}", c.to_bits()),
        UnaryOp::AddConst(c) => format!("AddConst#{:08x}", c.to_bits()),
        other => format!("{other:?}"),
    }
}

/// Canonical op descriptor: kind, scalar ops, and locally-renumbered label
/// pattern. Vertex names are deliberately not part of this.
pub(crate) fn op_sig(op: &EinSum) -> String {
    match op {
        EinSum::Input => "in".into(),
        EinSum::Unary { lx, lz, op, agg } => {
            let r = renumber(&[lx, lz]);
            format!("u:{}:{agg:?}:{:?}->{:?}", unary_sig(op), r[0], r[1])
        }
        EinSum::Binary {
            lx,
            ly,
            lz,
            join,
            agg,
        } => {
            let r = renumber(&[lx, ly, lz]);
            format!("b:{join:?}:{agg:?}:{:?},{:?}->{:?}", r[0], r[1], r[2])
        }
    }
}

fn count_distinct(keys: &[u64]) -> usize {
    let mut sorted = keys.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    sorted.len()
}

/// Canonicalize a graph: compute its signature and the canonical vertex
/// order. Deterministic, and invariant under label renaming and vertex
/// renumbering (see module docs).
pub fn canonicalize(g: &EinGraph) -> Canon {
    let verts = g.vertices();
    let n = verts.len();
    // Structural atom per vertex: canonical op descriptor + output bound.
    let atoms: Vec<String> = verts
        .iter()
        .map(|v| format!("{}|{:?}", op_sig(&v.op), v.bound))
        .collect();
    // Consumer adjacency with operand positions (which operand of the
    // consumer reads this vertex) — the upward context of the refinement.
    let mut cons: Vec<Vec<(usize, usize)>> = vec![vec![]; n];
    for v in verts {
        for (pos, &i) in v.inputs.iter().enumerate() {
            cons[i.0].push((v.id.0, pos));
        }
    }
    // Weisfeiler–Leman-style refinement: start from the atom hash, then
    // repeatedly mix in ordered operand keys and sorted consumer context
    // until the partition into key classes stabilizes.
    let mut key: Vec<u64> = atoms.iter().map(|a| fnv(a.as_bytes())).collect();
    let mut distinct = count_distinct(&key);
    for _ in 0..n {
        let mut next = vec![0u64; n];
        for (vi, v) in verts.iter().enumerate() {
            let mut h = scramble(key[vi]);
            for &i in &v.inputs {
                h = mix(h, key[i.0]);
            }
            let mut cs: Vec<(u64, usize)> =
                cons[vi].iter().map(|&(c, pos)| (key[c], pos)).collect();
            cs.sort_unstable();
            for (ck, pos) in cs {
                h = mix(mix(h, ck), pos as u64);
            }
            next[vi] = h;
        }
        key = next;
        let d = count_distinct(&key);
        if d == distinct {
            break;
        }
        distinct = d;
    }
    // Canonical order: refined key, then atom (guards key collisions),
    // then original index. Vertices still tied after refinement are
    // structurally interchangeable, so either order yields the same
    // signature text.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        key[a]
            .cmp(&key[b])
            .then_with(|| atoms[a].cmp(&atoms[b]))
            .then(a.cmp(&b))
    });
    let mut canon_of = vec![0usize; n];
    for (ci, &vi) in idx.iter().enumerate() {
        canon_of[vi] = ci;
    }
    // Exact signature text over the canonical order: atom plus canonical
    // indices of the ordered operands.
    let mut text = String::new();
    for (ci, &vi) in idx.iter().enumerate() {
        let ins: Vec<usize> = verts[vi].inputs.iter().map(|i| canon_of[i.0]).collect();
        write!(text, "{ci}:{}<-{:?};", atoms[vi], ins).unwrap();
    }
    Canon {
        signature: CanonSignature { text },
        canon_of,
        order: idx.into_iter().map(VertexId).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::expr::JoinOp;
    use crate::einsum::label::labels;

    /// The Experiment-1 chain, parameterized over labels and build order
    /// so tests can construct genuinely renamed/reordered clones.
    fn chain(names: [&str; 4], reorder: bool, s: usize) -> EinGraph {
        let mut g = EinGraph::new();
        let [li, lj, lk, lm] = names;
        let (spec_i, spec_j, spec_k, spec_m) = (labels(li), labels(lj), labels(lk), labels(lm));
        let (i, j, k, m) = (spec_i[0], spec_j[0], spec_k[0], spec_m[0]);
        if reorder {
            let d = g.input("D", vec![s, s]);
            let e = g.input("E", vec![s, s]);
            let de = g
                .add("DE", EinSum::contraction(vec![j, m], vec![m, k], vec![j, k]), vec![d, e])
                .unwrap();
            let a = g.input("A", vec![s, s]);
            let b = g.input("B", vec![s, s]);
            let c = g.input("C", vec![s, s]);
            let ab = g
                .add("AB", EinSum::contraction(vec![i, j], vec![j, k], vec![i, k]), vec![a, b])
                .unwrap();
            let cde = g
                .add("CDE", EinSum::contraction(vec![i, j], vec![j, k], vec![i, k]), vec![c, de])
                .unwrap();
            g.add(
                "Z",
                EinSum::elementwise(vec![i, k], vec![i, k], JoinOp::Add),
                vec![ab, cde],
            )
            .unwrap();
        } else {
            let a = g.input("A", vec![s, s]);
            let b = g.input("B", vec![s, s]);
            let c = g.input("C", vec![s, s]);
            let d = g.input("D", vec![s, s]);
            let e = g.input("E", vec![s, s]);
            let ab = g
                .add("AB", EinSum::contraction(vec![i, j], vec![j, k], vec![i, k]), vec![a, b])
                .unwrap();
            let de = g
                .add("DE", EinSum::contraction(vec![j, m], vec![m, k], vec![j, k]), vec![d, e])
                .unwrap();
            let cde = g
                .add("CDE", EinSum::contraction(vec![i, j], vec![j, k], vec![i, k]), vec![c, de])
                .unwrap();
            g.add(
                "Z",
                EinSum::elementwise(vec![i, k], vec![i, k], JoinOp::Add),
                vec![ab, cde],
            )
            .unwrap();
        }
        g
    }

    #[test]
    fn renamed_and_reordered_clone_matches() {
        let g1 = chain(["i", "j", "k", "m"], false, 16);
        let g2 = chain(["w", "x", "y", "z"], true, 16);
        let c1 = canonicalize(&g1);
        let c2 = canonicalize(&g2);
        assert_eq!(c1.signature, c2.signature);
        // the permutations compose into a real isomorphism: same atom at
        // every canonical position
        for ci in 0..g1.len() {
            let v1 = g1.vertex(c1.order[ci]);
            let v2 = g2.vertex(c2.order[ci]);
            assert_eq!(v1.bound, v2.bound);
            assert_eq!(op_sig(&v1.op), op_sig(&v2.op));
        }
    }

    #[test]
    fn batched_twins_preserve_canonical_equality() {
        // the serving batcher coalesces renamed-but-equivalent programs
        // into one batched twin; that is sound only if batching
        // preserves canonical equality (and inequality)
        let g1 = chain(["i", "j", "k", "m"], false, 16);
        let g2 = chain(["w", "x", "y", "z"], true, 16);
        assert_eq!(canonicalize(&g1).signature, canonicalize(&g2).signature);
        let b1 = g1.batched(4).unwrap();
        let b2 = g2.batched(4).unwrap();
        assert_eq!(canonicalize(&b1).signature, canonicalize(&b2).signature);
        // different size classes are distinct compilation units
        assert_ne!(
            canonicalize(&b1).signature,
            canonicalize(&g1.batched(2).unwrap()).signature
        );
        // and a twin never aliases its solo graph in the plan cache
        assert_ne!(canonicalize(&b1).signature, canonicalize(&g1).signature);
    }

    #[test]
    fn shape_change_misses() {
        let g1 = chain(["i", "j", "k", "m"], false, 16);
        let g2 = chain(["i", "j", "k", "m"], false, 32);
        assert_ne!(canonicalize(&g1).signature, canonicalize(&g2).signature);
    }

    #[test]
    fn operand_order_is_significant() {
        // A x B vs B^T-style contraction patterns must not collide.
        let mut g1 = EinGraph::new();
        let a = g1.input("A", vec![8, 8]);
        let b = g1.input("B", vec![8, 8]);
        g1.add("Z", EinSum::contraction(labels("i j"), labels("j k"), labels("i k")), vec![a, b])
            .unwrap();
        let mut g2 = EinGraph::new();
        let a = g2.input("A", vec![8, 8]);
        let b = g2.input("B", vec![8, 8]);
        g2.add("Z", EinSum::contraction(labels("i j"), labels("k j"), labels("i k")), vec![a, b])
            .unwrap();
        assert_ne!(canonicalize(&g1).signature, canonicalize(&g2).signature);
    }

    #[test]
    fn scalar_constants_are_significant() {
        let mk = |c: f32| {
            let mut g = EinGraph::new();
            let a = g.input("A", vec![4]);
            g.add("S", EinSum::map(labels("i"), UnaryOp::Scale(c)), vec![a]).unwrap();
            g
        };
        assert_ne!(
            canonicalize(&mk(0.5)).signature,
            canonicalize(&mk(0.25)).signature
        );
        assert_eq!(
            canonicalize(&mk(0.5)).signature,
            canonicalize(&mk(0.5)).signature
        );
    }

    #[test]
    fn names_are_not_significant() {
        let mut g1 = EinGraph::new();
        let a = g1.input("weights", vec![4, 4]);
        g1.add("out", EinSum::map(labels("i j"), UnaryOp::Relu), vec![a]).unwrap();
        let mut g2 = EinGraph::new();
        let a = g2.input("completely_different", vec![4, 4]);
        g2.add("names", EinSum::map(labels("i j"), UnaryOp::Relu), vec![a]).unwrap();
        assert_eq!(canonicalize(&g1).signature, canonicalize(&g2).signature);
    }

    #[test]
    fn identical_twin_inputs_distinguished_by_consumers() {
        // Two same-shape inputs are structurally identical in isolation;
        // the consumer-position refinement must still order them so the
        // operand edges line up across isomorphic builds.
        let mut g1 = EinGraph::new();
        let a = g1.input("A", vec![8, 4]);
        let b = g1.input("B", vec![4, 8]);
        g1.add("Z", EinSum::contraction(labels("i j"), labels("j k"), labels("i k")), vec![a, b])
            .unwrap();
        // swapped insertion order, same program
        let mut g2 = EinGraph::new();
        let b = g2.input("B", vec![4, 8]);
        let a = g2.input("A", vec![8, 4]);
        g2.add("Z", EinSum::contraction(labels("i j"), labels("j k"), labels("i k")), vec![a, b])
            .unwrap();
        let c1 = canonicalize(&g1);
        let c2 = canonicalize(&g2);
        assert_eq!(c1.signature, c2.signature);
        // square twin inputs: shapes equal, so only the consumer position
        // separates them
        let mut g3 = EinGraph::new();
        let a = g3.input("A", vec![8, 8]);
        let b = g3.input("B", vec![8, 8]);
        g3.add("Z", EinSum::contraction(labels("i j"), labels("j k"), labels("i k")), vec![a, b])
            .unwrap();
        let mut g4 = EinGraph::new();
        let b = g4.input("B", vec![8, 8]);
        let a = g4.input("A", vec![8, 8]);
        g4.add("Z", EinSum::contraction(labels("i j"), labels("j k"), labels("i k")), vec![a, b])
            .unwrap();
        let (c3, c4) = (canonicalize(&g3), canonicalize(&g4));
        assert_eq!(c3.signature, c4.signature);
        // the isomorphism maps operand 0 to operand 0: position in the
        // consumer's dep list is preserved by the canonical order
        let z3 = g3.by_name("Z").unwrap();
        let z4 = g4.by_name("Z").unwrap();
        let op0_g3 = g3.vertex(z3).inputs[0];
        let op0_g4 = g4.vertex(z4).inputs[0];
        assert_eq!(c3.canon_of[op0_g3.0], c4.canon_of[op0_g4.0]);
    }

    #[test]
    fn named_signature_distinguishes_renamings() {
        let mk = |names: [&str; 4]| chain(names, false, 8);
        let g1 = mk(["i", "j", "k", "m"]);
        let g2 = mk(["w", "x", "y", "z"]);
        let (c1, c2) = (canonicalize(&g1), canonicalize(&g2));
        // bare signatures collapse renamings; named signatures do not
        assert_eq!(c1.signature, c2.signature);
        assert_ne!(c1.named_signature(&g1), c2.named_signature(&g2));
        // but a true twin (same names, reordered build) still matches
        let g3 = chain(["i", "j", "k", "m"], true, 8);
        let c3 = canonicalize(&g3);
        assert_eq!(c1.named_signature(&g1), c3.named_signature(&g3));
    }

    /// Diamond: T feeds two distinguishable branches (Relu / Exp) that
    /// merge elementwise. `swap` flips both the insertion order of the
    /// branches *and* their operand positions at the merge.
    fn diamond(swap: bool, merge_swapped: bool) -> EinGraph {
        let mut g = EinGraph::new();
        let t = g.input("T", vec![8, 8]);
        let (l, r);
        if swap {
            r = g.add("R", EinSum::map(labels("i j"), UnaryOp::Exp), vec![t]).unwrap();
            l = g.add("L", EinSum::map(labels("i j"), UnaryOp::Relu), vec![t]).unwrap();
        } else {
            l = g.add("L", EinSum::map(labels("i j"), UnaryOp::Relu), vec![t]).unwrap();
            r = g.add("R", EinSum::map(labels("i j"), UnaryOp::Exp), vec![t]).unwrap();
        }
        let (a, b) = if merge_swapped { (r, l) } else { (l, r) };
        g.add(
            "Z",
            EinSum::elementwise(labels("i j"), labels("i j"), JoinOp::Sub),
            vec![a, b],
        )
        .unwrap();
        g
    }

    #[test]
    fn diamond_insertion_order_is_canonical() {
        // Same diamond, branches inserted in either order: one signature,
        // and the isomorphism maps Relu to Relu, Exp to Exp.
        let g1 = diamond(false, false);
        let g2 = diamond(true, false);
        let (c1, c2) = (canonicalize(&g1), canonicalize(&g2));
        assert_eq!(c1.signature, c2.signature);
        let l1 = g1.by_name("L").unwrap();
        let l2 = g2.by_name("L").unwrap();
        assert_eq!(c1.canon_of[l1.0], c2.canon_of[l2.0]);
    }

    #[test]
    fn diamond_merge_operand_order_is_significant() {
        // Z = L - R vs Z = R - L: the same multiset of vertices, wired
        // differently — these are different programs (Sub is not
        // symmetric, and Relu/Exp make the branches non-interchangeable),
        // so the signatures must differ.
        let g1 = diamond(false, false);
        let g2 = diamond(false, true);
        assert_ne!(canonicalize(&g1).signature, canonicalize(&g2).signature);
    }

    #[test]
    fn twin_inputs_swapped_operand_positions_remap_correctly() {
        // Z = A @ B vs Z = B @ A over same-shape inputs: isomorphic as
        // programs (rename A <-> B), so one signature — and the canon
        // isomorphism must align operand slot 0 with operand slot 0, so
        // a cache-hit remap feeds the right tensor to the right side.
        let build = |swap: bool| {
            let mut g = EinGraph::new();
            let a = g.input("A", vec![8, 8]);
            let b = g.input("B", vec![8, 8]);
            let (x, y) = if swap { (b, a) } else { (a, b) };
            g.add(
                "Z",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![x, y],
            )
            .unwrap();
            g
        };
        let g1 = build(false);
        let g2 = build(true);
        let (c1, c2) = (canonicalize(&g1), canonicalize(&g2));
        assert_eq!(c1.signature, c2.signature);
        let op0_g1 = g1.vertex(g1.by_name("Z").unwrap()).inputs[0]; // A
        let op0_g2 = g2.vertex(g2.by_name("Z").unwrap()).inputs[0]; // B
        assert_eq!(c1.canon_of[op0_g1.0], c2.canon_of[op0_g2.0]);
        // ... which for an asymmetric-shape twin is also shape-checked:
        let mut g3 = EinGraph::new();
        let a = g3.input("A", vec![8, 4]);
        let b = g3.input("B", vec![4, 8]);
        g3.add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
        let c3 = canonicalize(&g3);
        let v = g3.vertex(g3.by_name("Z").unwrap()).inputs[0];
        assert_eq!(g3.vertex(c3.order[c3.canon_of[v.0]]).bound, vec![8, 4]);
    }

    #[test]
    fn same_shape_different_label_role_misses_under_named_signatures() {
        // Two structurally identical single-contraction programs at the
        // same shapes whose only difference is a label *name* ("b" batch
        // vs "s" sequence). Bare signatures collapse them — correct for
        // structural strategies — but role-driven strategies plan by
        // label name, so the named signature must keep them apart.
        let build = |lead: &str| {
            let mut g = EinGraph::new();
            let x = g.input("X", vec![16, 8]);
            let w = g.input("W", vec![8, 16]);
            let spec = format!("{lead} j");
            g.add(
                "Z",
                EinSum::contraction(labels(&spec), labels("j k"), labels(&format!("{lead} k"))),
                vec![x, w],
            )
            .unwrap();
            g
        };
        let gb = build("b");
        let gs = build("s");
        let (cb, cs) = (canonicalize(&gb), canonicalize(&gs));
        assert_eq!(cb.signature, cs.signature);
        assert_ne!(cb.named_signature(&gb), cs.named_signature(&gs));
        // same names -> named signatures agree
        let gb2 = build("b");
        assert_eq!(cb.named_signature(&gb), canonicalize(&gb2).named_signature(&gb2));
    }

    #[test]
    fn canon_maps_are_inverse_permutations() {
        let g = chain(["i", "j", "k", "m"], true, 8);
        let c = canonicalize(&g);
        for v in 0..g.len() {
            assert_eq!(c.order[c.canon_of[v]], VertexId(v));
        }
        assert!(c.signature.text().contains("b:Mul:Sum"));
        assert_ne!(c.signature.digest(), 0);
    }
}

//! Reverse-mode automatic differentiation of EinSum graphs.
//!
//! The Einsummable system trains models by differentiating relational
//! computations (Tang et al., ICML 2023 — reference [50] of the paper);
//! gradients come out as *more EinSum vertices*, so the same EinDecomp
//! planner decomposes forward and backward together. This module builds
//! the backward graph:
//!
//! * contraction `Z = sum_agg X (*) Y`  ->  `dX = sum X-free(dZ (*) Y)`
//!   (the classic einsum transpose rule: swap the differentiated operand
//!   with the output gradient and contract over what `l_X` lacks);
//! * elementwise Add/Sub/Mul/Div and the softmax SubExp join;
//! * unary maps via pointwise derivative rules;
//! * unary Sum-reductions broadcast the gradient back (expressed with the
//!   `Right` join against the primal, since EinSum has no broadcast);
//! * Max/Min reductions are treated as stop-gradient. This matches the
//!   standard numerically-stable-softmax treatment (the subtracted max
//!   cancels in the softmax gradient), which is the only place the model
//!   macros use them.
//!
//! Gradients of a vertex consumed `k` times accumulate with `k-1`
//! elementwise adds, in reverse topological order.

use super::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use super::graph::{EinGraph, VertexId};
use super::label::{concat_dedup, difference, LabelList};
use crate::error::{Error, Result};
use std::collections::HashMap;

/// Result of [`grad`]: the extended graph, the seed-input vertex (caller
/// feeds ones shaped like the loss), and the gradient vertex for each
/// requested input.
pub struct GradGraph {
    pub graph: EinGraph,
    pub seed: VertexId,
    pub grads: HashMap<VertexId, VertexId>,
}

/// Append the backward pass of `loss` w.r.t. `wrt` onto (a clone of) `g`.
pub fn grad(g: &EinGraph, loss: VertexId, wrt: &[VertexId]) -> Result<GradGraph> {
    let mut out = g.clone();
    let loss_bound = g.vertex(loss).bound.clone();
    let seed = out.input("d_seed", loss_bound);

    // adjoints[v]: list of gradient contributions to v's output
    let mut contrib: HashMap<VertexId, Vec<VertexId>> = HashMap::new();
    contrib.entry(loss).or_default().push(seed);

    // walk original vertices in reverse topological order
    for vid in g.topo_order().into_iter().rev() {
        let Some(parts) = contrib.remove(&vid) else {
            continue;
        };
        let vert = g.vertex(vid);
        // sum multiple contributions
        let lz = match vert.op.lz() {
            Some(lz) => lz.clone(),
            None => {
                // input vertex: just record the accumulated gradient
                let total = accumulate(&mut out, &vert.name, parts, vert.bound.len())?;
                contrib.insert(vid, vec![total]);
                continue;
            }
        };
        let dz = accumulate(&mut out, &vert.name, parts, lz.len())?;
        // push through the operation
        match vert.op.clone() {
            EinSum::Input => unreachable!(),
            EinSum::Unary { lx, lz, op, agg } => {
                let dx = grad_unary(&mut out, &vert.name, vid, vert.inputs[0], &lx, &lz, op, agg, dz)?;
                if let Some(dx) = dx {
                    contrib.entry(vert.inputs[0]).or_default().push(dx);
                }
            }
            EinSum::Binary {
                lx,
                ly,
                lz,
                join,
                agg,
            } => {
                if agg != AggOp::Sum && !vert.op.lagg().is_empty() {
                    return Err(Error::InvalidEinsum(format!(
                        "autodiff: non-Sum aggregation in {} is not differentiable here",
                        vert.name
                    )));
                }
                let (x, y) = (vert.inputs[0], vert.inputs[1]);
                let (dx, dy) =
                    grad_binary(&mut out, &vert.name, vid, x, y, &lx, &ly, &lz, join, dz)?;
                if let Some(dx) = dx {
                    contrib.entry(x).or_default().push(dx);
                }
                if let Some(dy) = dy {
                    contrib.entry(y).or_default().push(dy);
                }
            }
        }
    }

    let mut grads = HashMap::new();
    for &w in wrt {
        let parts = contrib.remove(&w).unwrap_or_default();
        if parts.is_empty() {
            return Err(Error::InvalidEinsum(format!(
                "no gradient path from loss to {}",
                g.vertex(w).name
            )));
        }
        let total = accumulate(&mut out, &g.vertex(w).name, parts, g.vertex(w).bound.len())?;
        // Wrap in an identity so every requested gradient is a graph
        // *output* even when the raw adjoint vertex feeds other adjoints
        // (e.g. the SubExp dX tensor is reused by its dC reduction).
        let rank = g.vertex(w).bound.len();
        let labs: LabelList = (0..rank)
            .map(|i| super::label::Label::new(&format!("_g{i}")))
            .collect();
        let wrapped = out.add(
            &format!("grad_{}", g.vertex(w).name),
            EinSum::map(labs, UnaryOp::Identity),
            vec![total],
        )?;
        grads.insert(w, wrapped);
    }
    Ok(GradGraph {
        graph: out,
        seed,
        grads,
    })
}

/// Sum a list of same-shaped gradient vertices.
fn accumulate(
    out: &mut EinGraph,
    name: &str,
    mut parts: Vec<VertexId>,
    rank: usize,
) -> Result<VertexId> {
    let labs: LabelList = (0..rank)
        .map(|i| super::label::Label::new(&format!("_g{i}")))
        .collect();
    let mut acc = parts.remove(0);
    for (i, p) in parts.into_iter().enumerate() {
        acc = out.add(
            &format!("d_{name}.acc{i}"),
            EinSum::elementwise(labs.clone(), labs.clone(), JoinOp::Add),
            vec![acc, p],
        )?;
    }
    Ok(acc)
}

/// dX for a unary vertex; `None` means stop-gradient.
#[allow(clippy::too_many_arguments)]
fn grad_unary(
    out: &mut EinGraph,
    name: &str,
    z: VertexId,
    x: VertexId,
    lx: &LabelList,
    lz: &LabelList,
    op: UnaryOp,
    agg: AggOp,
    dz: VertexId,
) -> Result<Option<VertexId>> {
    let dropped = difference(lx, lz);
    // 1. reduction part: broadcast dZ back over the dropped labels
    let dz_full = if dropped.is_empty() {
        // pure map/transpose: re-orient dZ (labelled lz) to lx order is
        // implicit — downstream ops reference labels, not positions.
        dz
    } else {
        match agg {
            AggOp::Sum => {
                // spray dZ across lx using the primal X for shape
                out.add(
                    &format!("d_{name}.bcast"),
                    EinSum::Binary {
                        lx: lx.clone(),
                        ly: lz.clone(),
                        lz: lx.clone(),
                        join: JoinOp::Right,
                        agg: AggOp::Sum,
                    },
                    vec![x, dz],
                )?
            }
            // Max/Min reductions: stop-gradient (see module docs)
            _ => return Ok(None),
        }
    };
    // 2. map part: chain rule through the pointwise function
    let dx = match op {
        UnaryOp::Identity => {
            if lz.len() == lx.len() && lz != lx {
                // pure transpose: re-express dz (over lz) in lx order
                out.add(
                    &format!("d_{name}.perm"),
                    EinSum::reduce(lz.clone(), lx.clone(), AggOp::Sum),
                    vec![dz_full],
                )?
            } else {
                dz_full
            }
        }
        UnaryOp::Scale(c) => out.add(
            &format!("d_{name}.scale"),
            EinSum::map(lx.clone(), UnaryOp::Scale(c)),
            vec![dz_full],
        )?,
        UnaryOp::Neg => out.add(
            &format!("d_{name}.neg"),
            EinSum::map(lx.clone(), UnaryOp::Neg),
            vec![dz_full],
        )?,
        UnaryOp::AddConst(_) => dz_full,
        UnaryOp::Relu => {
            let mask = out.add(
                &format!("d_{name}.mask"),
                EinSum::map(lx.clone(), UnaryOp::ReluGrad),
                vec![x],
            )?;
            out.add(
                &format!("d_{name}.mul"),
                EinSum::elementwise(lx.clone(), lx.clone(), JoinOp::Mul),
                vec![dz_full, mask],
            )?
        }
        UnaryOp::Exp => {
            // d exp = exp(x) = Z itself (only valid for pure maps)
            if !difference(lx, lz).is_empty() {
                return Err(Error::InvalidEinsum(format!(
                    "autodiff: exp+reduce in one vertex unsupported ({name})"
                )));
            }
            out.add(
                &format!("d_{name}.mul"),
                EinSum::elementwise(lx.clone(), lx.clone(), JoinOp::Mul),
                vec![dz_full, z],
            )?
        }
        UnaryOp::Square => {
            let two_x = out.add(
                &format!("d_{name}.2x"),
                EinSum::map(lx.clone(), UnaryOp::Scale(2.0)),
                vec![x],
            )?;
            out.add(
                &format!("d_{name}.mul"),
                EinSum::elementwise(lx.clone(), lx.clone(), JoinOp::Mul),
                vec![dz_full, two_x],
            )?
        }
        other => {
            return Err(Error::InvalidEinsum(format!(
                "autodiff: unary {other:?} not supported ({name})"
            )))
        }
    };
    Ok(Some(dx))
}

/// (dX, dY) for a binary vertex.
#[allow(clippy::too_many_arguments)]
fn grad_binary(
    out: &mut EinGraph,
    name: &str,
    z: VertexId,
    x: VertexId,
    y: VertexId,
    lx: &LabelList,
    ly: &LabelList,
    lz: &LabelList,
    join: JoinOp,
    dz: VertexId,
) -> Result<(Option<VertexId>, Option<VertexId>)> {
    // helper: contraction dOp = sum_free( dZ (x) Other ) -> l_target
    let contract =
        |out: &mut EinGraph, tag: &str, other: VertexId, lo: &LabelList, lt: &LabelList| {
            out.add(
                &format!("d_{name}.{tag}"),
                EinSum::contraction(lz.clone(), lo.clone(), lt.clone()),
                vec![dz, other],
            )
        };
    // helper: reduce dZ (over lz) down to l_target (for +/- style joins
    // where the operand may index fewer labels)
    let reduce_to = |out: &mut EinGraph, tag: &str, lt: &LabelList| {
        if lt == lz {
            Ok(dz)
        } else {
            out.add(
                &format!("d_{name}.{tag}"),
                EinSum::reduce(lz.clone(), lt.clone(), AggOp::Sum),
                vec![dz],
            )
        }
    };
    match join {
        JoinOp::Mul => {
            // works uniformly for contraction AND (broadcast) elementwise:
            // dX = sum_{labels not in lx} dZ * Y ; symmetric for Y.
            // Valid when every l_X label appears in l_Z or l_Y (no
            // operand-private aggregated labels) — true for all our model
            // graphs; reject otherwise.
            let ok_x = lx.iter().all(|l| lz.contains(l) || ly.contains(l));
            let ok_y = ly.iter().all(|l| lz.contains(l) || lx.contains(l));
            if !ok_x || !ok_y {
                return Err(Error::InvalidEinsum(format!(
                    "autodiff: operand-private aggregated label in {name}"
                )));
            }
            let dx = contract(out, "dx", y, ly, lx)?;
            let dy = contract(out, "dy", x, lx, ly)?;
            Ok((Some(dx), Some(dy)))
        }
        JoinOp::Add => {
            let dx = reduce_to(out, "dx", lx)?;
            let dy = reduce_to(out, "dy", ly)?;
            Ok((Some(dx), Some(dy)))
        }
        JoinOp::Sub => {
            let dx = reduce_to(out, "dx", lx)?;
            let dy0 = reduce_to(out, "dy0", ly)?;
            let dy = out.add(
                &format!("d_{name}.dyneg"),
                EinSum::map(ly.clone(), UnaryOp::Neg),
                vec![dy0],
            )?;
            Ok((Some(dx), Some(dy)))
        }
        JoinOp::Div => {
            // z = x / y (elementwise, possibly broadcast on y):
            // dX = dZ / Y ; dY = -sum(dZ * Z) / Y
            let dx_full = out.add(
                &format!("d_{name}.dxdiv"),
                EinSum::Binary {
                    lx: lz.clone(),
                    ly: ly.clone(),
                    lz: lz.clone(),
                    join: JoinOp::Div,
                    agg: AggOp::Sum,
                },
                vec![dz, y],
            )?;
            let dx = if lx == lz {
                dx_full
            } else {
                out.add(
                    &format!("d_{name}.dxred"),
                    EinSum::reduce(lz.clone(), lx.clone(), AggOp::Sum),
                    vec![dx_full],
                )?
            };
            let dzz = out.add(
                &format!("d_{name}.dzz"),
                EinSum::elementwise(lz.clone(), lz.clone(), JoinOp::Mul),
                vec![dz, z],
            )?;
            let red = out.add(
                &format!("d_{name}.dyred"),
                EinSum::reduce(lz.clone(), ly.clone(), AggOp::Sum),
                vec![dzz],
            )?;
            let div = out.add(
                &format!("d_{name}.dydiv"),
                EinSum::elementwise(ly.clone(), ly.clone(), JoinOp::Div),
                vec![red, y],
            )?;
            let dy = out.add(
                &format!("d_{name}.dyneg"),
                EinSum::map(ly.clone(), UnaryOp::Neg),
                vec![div],
            )?;
            Ok((Some(dx), Some(dy)))
        }
        JoinOp::SubExp => {
            // z = e^(x - c): dX = dZ * Z ; dC = -sum(dZ * Z)
            let dzz = out.add(
                &format!("d_{name}.dzz"),
                EinSum::elementwise(lz.clone(), lz.clone(), JoinOp::Mul),
                vec![dz, z],
            )?;
            let dx = if lx == lz {
                dzz
            } else {
                out.add(
                    &format!("d_{name}.dxred"),
                    EinSum::reduce(lz.clone(), lx.clone(), AggOp::Sum),
                    vec![dzz],
                )?
            };
            let red = out.add(
                &format!("d_{name}.dcred"),
                EinSum::reduce(lz.clone(), ly.clone(), AggOp::Sum),
                vec![dzz],
            )?;
            let dy = out.add(
                &format!("d_{name}.dcneg"),
                EinSum::map(ly.clone(), UnaryOp::Neg),
                vec![red],
            )?;
            Ok((Some(dx), Some(dy)))
        }
        other => Err(Error::InvalidEinsum(format!(
            "autodiff: join {other:?} not supported ({name})"
        ))),
    }
}

/// Convenience: `l_X (.) l_Y` (kept for future broadcast support).
#[allow(dead_code)]
fn joint(lx: &LabelList, ly: &LabelList) -> LabelList {
    concat_dedup(lx, ly)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::label::labels;
    use crate::runtime::native::eval_einsum;
    use crate::runtime::NativeEngine;
    use crate::sim::{Cluster, NetworkProfile};
    use crate::tensor::Tensor;

    /// Evaluate a graph densely (single worker) and return named outputs.
    fn run(
        g: &EinGraph,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> HashMap<VertexId, Tensor> {
        let plan = crate::decomp::plan_graph(
            g,
            &crate::decomp::PlannerConfig {
                p: 1,
                mode: crate::decomp::PlanMode::Greedy,
                off_path_cost: false,
                ..Default::default()
            },
        )
        .unwrap();
        let cluster = Cluster::new(1, NetworkProfile::loopback());
        let (outs, _) = cluster
            .execute(g, &plan, &NativeEngine::new(), inputs)
            .unwrap();
        outs
    }

    /// loss = sum((X W)^2) — check dW against finite differences.
    #[test]
    fn grad_matmul_square_sum_matches_fd() {
        let mut g = EinGraph::new();
        let x = g.input("X", vec![4, 5]);
        let w = g.input("W", vec![5, 3]);
        let z = g
            .add(
                "Z",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![x, w],
            )
            .unwrap();
        let sq = g
            .add("Sq", EinSum::map(labels("i k"), UnaryOp::Square), vec![z])
            .unwrap();
        let loss = g
            .add("L", EinSum::reduce(labels("i k"), vec![], AggOp::Sum), vec![sq])
            .unwrap();
        let gg = grad(&g, loss, &[w, x]).unwrap();
        gg.graph.validate().unwrap();

        let tx = Tensor::random(&[4, 5], 1);
        let tw = Tensor::random(&[5, 3], 2);
        let mut inputs = HashMap::new();
        inputs.insert(x, tx.clone());
        inputs.insert(w, tw.clone());
        inputs.insert(gg.seed, Tensor::scalar(1.0));
        let outs = run(&gg.graph, &inputs);
        let dw = &outs[&gg.grads[&w]];
        let dx = &outs[&gg.grads[&x]];
        assert_eq!(dw.shape(), &[5, 3]);
        assert_eq!(dx.shape(), &[4, 5]);

        // finite differences on dW
        let f = |tw: &Tensor| -> f32 {
            let z = eval_einsum(&g.vertex(z).op, &[&tx, tw]).unwrap();
            z.data().iter().map(|v| v * v).sum()
        };
        let eps = 1e-3;
        for &(i, j) in &[(0usize, 0usize), (2, 1), (4, 2)] {
            let mut plus = tw.clone();
            plus.set(&[i, j], tw.at(&[i, j]) + eps);
            let mut minus = tw.clone();
            minus.set(&[i, j], tw.at(&[i, j]) - eps);
            let fd = (f(&plus) - f(&minus)) / (2.0 * eps);
            let an = dw.at(&[i, j]);
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
                "dW[{i},{j}]: fd {fd} vs {an}"
            );
        }
    }

    /// Autodiff of the FFNN forward must match the hand-written backward
    /// in models::ffnn.
    #[test]
    fn grad_matches_handwritten_ffnn() {
        use crate::models::ffnn::{ffnn_step, step_inputs, FfnnState};
        let step = ffnn_step(6, 8, 5, 3).unwrap();
        let state = FfnnState::init(8, 5, 3, 4);
        let (xb, tb) = crate::data::classifier_batch(6, 8, 3, 0.6, 9);
        // hand-written grads
        let inputs = step_inputs(&step, &state, xb.clone(), tb.clone());
        let outs = run(&step.graph, &inputs);
        let dw1_hand = outs[&step.dw1].clone();
        let dw2_hand = outs[&step.dw2].clone();
        // autodiff grads of the same loss
        let gg = grad(&step.graph, step.loss, &[step.w1, step.w2]).unwrap();
        let mut inputs2 = step_inputs(&step, &state, xb, tb);
        inputs2.insert(gg.seed, Tensor::scalar(1.0));
        let outs2 = run(&gg.graph, &inputs2);
        let dw1_auto = &outs2[&gg.grads[&step.w1]];
        let dw2_auto = &outs2[&gg.grads[&step.w2]];
        assert!(
            dw1_auto.allclose(&dw1_hand, 1e-3, 1e-4),
            "dW1 mismatch: {}",
            dw1_auto.max_abs_diff(&dw1_hand).unwrap()
        );
        assert!(dw2_auto.allclose(&dw2_hand, 1e-3, 1e-4));
    }

    /// Softmax (with its Max stop-gradient) differentiates correctly:
    /// compare against finite differences of sum(softmax(X) * C).
    #[test]
    fn grad_softmax_matches_fd() {
        let mut g = EinGraph::new();
        let x = g.input("X", vec![3, 4]);
        let c = g.input("C", vec![3, 4]);
        let sm = crate::einsum::macros::softmax(&mut g, "sm", x, &labels("i j")).unwrap();
        let prod = g
            .add(
                "P",
                EinSum::elementwise(labels("i j"), labels("i j"), JoinOp::Mul),
                vec![sm, c],
            )
            .unwrap();
        let loss = g
            .add("L", EinSum::reduce(labels("i j"), vec![], AggOp::Sum), vec![prod])
            .unwrap();
        let gg = grad(&g, loss, &[x]).unwrap();
        let tx = Tensor::random(&[3, 4], 11);
        let tc = Tensor::random(&[3, 4], 12);
        let mut inputs = HashMap::new();
        inputs.insert(x, tx.clone());
        inputs.insert(c, tc.clone());
        inputs.insert(gg.seed, Tensor::scalar(1.0));
        let outs = run(&gg.graph, &inputs);
        let dx = &outs[&gg.grads[&x]];

        let f = |tx: &Tensor| -> f32 {
            let mut total = 0.0f32;
            for i in 0..3 {
                let row: Vec<f32> = (0..4).map(|j| tx.at(&[i, j])).collect();
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let e: Vec<f32> = row.iter().map(|v| (v - m).exp()).collect();
                let s: f32 = e.iter().sum();
                for j in 0..4 {
                    total += e[j] / s * tc.at(&[i, j]);
                }
            }
            total
        };
        let eps = 1e-3;
        for &(i, j) in &[(0usize, 0usize), (1, 2), (2, 3)] {
            let mut plus = tx.clone();
            plus.set(&[i, j], tx.at(&[i, j]) + eps);
            let mut minus = tx.clone();
            minus.set(&[i, j], tx.at(&[i, j]) - eps);
            let fd = (f(&plus) - f(&minus)) / (2.0 * eps);
            let an = dx.at(&[i, j]);
            assert!(
                (fd - an).abs() < 5e-3 * (1.0 + fd.abs()),
                "dX[{i},{j}]: fd {fd} vs autodiff {an}"
            );
        }
    }

    #[test]
    fn grad_rejects_unreachable() {
        let mut g = EinGraph::new();
        let x = g.input("X", vec![2, 2]);
        let w = g.input("W", vec![2, 2]); // never used
        let loss = g
            .add("L", EinSum::reduce(labels("i j"), vec![], AggOp::Sum), vec![x])
            .unwrap();
        assert!(grad(&g, loss, &[w]).is_err());
    }

    /// The backward graph is plannable and decomposes correctly (p=4
    /// matches p=1).
    #[test]
    fn grad_graph_decomposes() {
        let mut g = EinGraph::new();
        let x = g.input("X", vec![8, 8]);
        let w = g.input("W", vec![8, 8]);
        let z = g
            .add(
                "Z",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![x, w],
            )
            .unwrap();
        let r = g
            .add("R", EinSum::map(labels("i k"), UnaryOp::Relu), vec![z])
            .unwrap();
        let loss = g
            .add("L", EinSum::reduce(labels("i k"), vec![], AggOp::Sum), vec![r])
            .unwrap();
        let gg = grad(&g, loss, &[w]).unwrap();
        let tx = Tensor::random(&[8, 8], 5);
        let tw = Tensor::random(&[8, 8], 6);
        let mut inputs = HashMap::new();
        inputs.insert(x, tx);
        inputs.insert(w, tw);
        inputs.insert(gg.seed, Tensor::scalar(1.0));
        let o1 = run(&gg.graph, &inputs);
        // p=4 via the full planner
        let plan = crate::decomp::plan_graph(
            &gg.graph,
            &crate::decomp::PlannerConfig {
                p: 4,
                mode: crate::decomp::PlanMode::Linearized,
                off_path_cost: true,
                ..Default::default()
            },
        )
        .unwrap();
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let (o4, _) = cluster
            .execute(&gg.graph, &plan, &NativeEngine::new(), &inputs)
            .unwrap();
        let gvert = gg.grads[&w];
        assert!(o4[&gvert].allclose(&o1[&gvert], 1e-3, 1e-4));
    }
}

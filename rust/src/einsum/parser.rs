//! Textual front-end for EinSum programs.
//!
//! Two levels are provided:
//!
//! 1. [`parse_spec`] — classic `numpy.einsum`-style subscript strings,
//!    `"ij,jk->ik"` (single-character labels) or the multi-character form
//!    `"b i j, b j k -> b i k"` (whitespace-separated labels). Returns the
//!    operand/output label lists of a contraction.
//!
//! 2. [`parse_program`] — a small line-oriented program format used by the
//!    CLI, mirroring how EinGraphs are supplied to the system:
//!
//!    ```text
//!    input X [128, 256]
//!    input Y [256, 64]
//!    Z  = einsum ij,jk->ik X Y           # Mul/Sum contraction
//!    D  = einsum ij,jk->ik X Y agg=max join=absdiff
//!    R  = map relu Z
//!    S  = reduce sum ij->i R
//!    E  = ew add Z Z                     # elementwise binary
//!    ```

use super::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use super::graph::{EinGraph, VertexId};
use super::label::{Label, LabelList};
use crate::error::{Error, Result};
use std::collections::HashMap;

/// Parse one operand's subscripts: either all single-char (`"ij"`) or
/// whitespace-separated multi-char (`"i j"` / `"seq head"`).
fn parse_operand(s: &str) -> Result<LabelList> {
    let s = s.trim();
    if s.is_empty() {
        return Ok(vec![]);
    }
    if s.contains(char::is_whitespace) {
        Ok(s.split_whitespace().map(Label::new).collect())
    } else {
        Ok(s.chars()
            .map(|c| {
                if c.is_alphanumeric() || c == '\'' || c == '_' {
                    Ok(Label::new(&c.to_string()))
                } else {
                    Err(Error::Parse(format!("bad subscript char {c:?} in {s:?}")))
                }
            })
            .collect::<Result<Vec<_>>>()?)
    }
}

/// Parse an einsum subscript spec `"lhs0,lhs1->rhs"` (or unary
/// `"lhs->rhs"`). Returns (operand label lists, output label list).
pub fn parse_spec(spec: &str) -> Result<(Vec<LabelList>, LabelList)> {
    let (lhs, rhs) = spec
        .split_once("->")
        .ok_or_else(|| Error::Parse(format!("spec {spec:?} missing '->'")))?;
    let operands = lhs
        .split(',')
        .map(parse_operand)
        .collect::<Result<Vec<_>>>()?;
    if operands.is_empty() || operands.len() > 2 {
        return Err(Error::Parse(format!(
            "spec {spec:?}: {} operands (1 or 2 supported)",
            operands.len()
        )));
    }
    let out = parse_operand(rhs)?;
    Ok((operands, out))
}

/// Build a contraction-style [`EinSum`] from a spec string plus optional
/// agg/join overrides.
pub fn einsum_from_spec(spec: &str, agg: AggOp, join: JoinOp) -> Result<EinSum> {
    let (ops, lz) = parse_spec(spec)?;
    match ops.len() {
        1 => Ok(EinSum::Unary {
            lx: ops[0].clone(),
            lz,
            op: UnaryOp::Identity,
            agg,
        }),
        2 => Ok(EinSum::Binary {
            lx: ops[0].clone(),
            ly: ops[1].clone(),
            lz,
            join,
            agg,
        }),
        _ => unreachable!(),
    }
}

fn parse_agg(s: &str) -> Result<AggOp> {
    match s {
        "sum" => Ok(AggOp::Sum),
        "max" => Ok(AggOp::Max),
        "min" => Ok(AggOp::Min),
        "prod" => Ok(AggOp::Prod),
        _ => Err(Error::Parse(format!("unknown agg op {s:?}"))),
    }
}

fn parse_join(s: &str) -> Result<JoinOp> {
    match s {
        "mul" => Ok(JoinOp::Mul),
        "add" => Ok(JoinOp::Add),
        "sub" => Ok(JoinOp::Sub),
        "div" => Ok(JoinOp::Div),
        "sqdiff" => Ok(JoinOp::SquaredDiff),
        "absdiff" => Ok(JoinOp::AbsDiff),
        "subexp" => Ok(JoinOp::SubExp),
        "max" => Ok(JoinOp::Max),
        "min" => Ok(JoinOp::Min),
        _ => Err(Error::Parse(format!("unknown join op {s:?}"))),
    }
}

fn parse_unary(s: &str) -> Result<UnaryOp> {
    if let Some(c) = s.strip_prefix("scale:") {
        let v: f32 = c
            .parse()
            .map_err(|_| Error::Parse(format!("bad scale constant {c:?}")))?;
        return Ok(UnaryOp::Scale(v));
    }
    if let Some(c) = s.strip_prefix("addc:") {
        let v: f32 = c
            .parse()
            .map_err(|_| Error::Parse(format!("bad add constant {c:?}")))?;
        return Ok(UnaryOp::AddConst(v));
    }
    match s {
        "id" | "identity" => Ok(UnaryOp::Identity),
        "exp" => Ok(UnaryOp::Exp),
        "neg" => Ok(UnaryOp::Neg),
        "relu" => Ok(UnaryOp::Relu),
        "relugrad" => Ok(UnaryOp::ReluGrad),
        "recip" => Ok(UnaryOp::Recip),
        "sqrt" => Ok(UnaryOp::Sqrt),
        "rsqrt" => Ok(UnaryOp::Rsqrt),
        "square" => Ok(UnaryOp::Square),
        "silu" => Ok(UnaryOp::Silu),
        "sigmoid" => Ok(UnaryOp::Sigmoid),
        "tanh" => Ok(UnaryOp::Tanh),
        "ln" => Ok(UnaryOp::Ln),
        _ => Err(Error::Parse(format!("unknown unary op {s:?}"))),
    }
}

fn parse_bound(s: &str) -> Result<Vec<usize>> {
    let s = s.trim();
    let inner = s
        .strip_prefix('[')
        .and_then(|x| x.strip_suffix(']'))
        .ok_or_else(|| Error::Parse(format!("bound {s:?} must look like [8, 8]")))?;
    inner
        .split(',')
        .filter(|x| !x.trim().is_empty())
        .map(|x| {
            x.trim()
                .parse::<usize>()
                .map_err(|_| Error::Parse(format!("bad bound entry {x:?}")))
        })
        .collect()
}

/// Parse a whole-program text into an [`EinGraph`]. See module docs for the
/// format. `#`-comments and blank lines are skipped.
pub fn parse_program(text: &str) -> Result<EinGraph> {
    let mut g = EinGraph::new();
    let mut env: HashMap<String, VertexId> = HashMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: String| Error::Parse(format!("line {}: {msg}", lineno + 1));
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks[0] == "input" {
            if toks.len() < 3 {
                return Err(err("input NAME [dims]".into()));
            }
            let name = toks[1];
            let bound = parse_bound(&toks[2..].join(" "))?;
            let id = g.input(name, bound);
            env.insert(name.to_string(), id);
            continue;
        }
        // NAME = <cmd> ...
        if toks.len() < 3 || toks[1] != "=" {
            return Err(err(format!("expected 'NAME = cmd ...', got {line:?}")));
        }
        let name = toks[0];
        let cmd = toks[2];
        let rest = &toks[3..];
        let lookup = |n: &str| -> Result<VertexId> {
            env.get(n)
                .copied()
                .ok_or_else(|| Error::Parse(format!("line {}: unknown tensor {n:?}", lineno + 1)))
        };
        let id = match cmd {
            "einsum" => {
                if rest.len() < 2 {
                    return Err(err("einsum SPEC X [Y] [agg=..] [join=..]".into()));
                }
                let spec = rest[0];
                let mut agg = AggOp::Sum;
                let mut join = JoinOp::Mul;
                let mut args = Vec::new();
                for t in &rest[1..] {
                    if let Some(v) = t.strip_prefix("agg=") {
                        agg = parse_agg(v)?;
                    } else if let Some(v) = t.strip_prefix("join=") {
                        join = parse_join(v)?;
                    } else {
                        args.push(lookup(t)?);
                    }
                }
                let e = einsum_from_spec(spec, agg, join)?;
                if e.arity() != args.len() {
                    return Err(err(format!(
                        "spec has {} operands but {} tensors given",
                        e.arity(),
                        args.len()
                    )));
                }
                g.add(name, e, args)?
            }
            "map" => {
                if rest.len() != 2 {
                    return Err(err("map OP X".into()));
                }
                let op = parse_unary(rest[0])?;
                let x = lookup(rest[1])?;
                let lx = default_labels(g.vertex(x).bound.len());
                g.add(name, EinSum::map(lx, op), vec![x])?
            }
            "reduce" => {
                if rest.len() != 3 {
                    return Err(err("reduce AGG SPEC X".into()));
                }
                let agg = parse_agg(rest[0])?;
                let (ops, lz) = parse_spec(rest[1])?;
                if ops.len() != 1 {
                    return Err(err("reduce takes a unary spec like ij->i".into()));
                }
                let x = lookup(rest[2])?;
                g.add(name, EinSum::reduce(ops[0].clone(), lz, agg), vec![x])?
            }
            "ew" => {
                if rest.len() != 3 {
                    return Err(err("ew JOIN X Y".into()));
                }
                let join = parse_join(rest[0])?;
                let x = lookup(rest[1])?;
                let y = lookup(rest[2])?;
                let lx = default_labels(g.vertex(x).bound.len());
                let ly = default_labels(g.vertex(y).bound.len());
                g.add(name, EinSum::elementwise(lx, ly, join), vec![x, y])?
            }
            _ => return Err(err(format!("unknown command {cmd:?}"))),
        };
        env.insert(name.to_string(), id);
    }
    g.validate()?;
    Ok(g)
}

/// Fresh canonical labels `_d0.._dn` for rank-n elementwise ops where the
/// user did not name dimensions (shared with the lazy [`crate::einsum::lazy`]
/// frontend).
pub(crate) fn default_labels(rank: usize) -> LabelList {
    (0..rank).map(|i| Label::new(&format!("_d{i}"))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::label::labels;

    #[test]
    fn parse_single_char_spec() {
        let (ops, out) = parse_spec("ij,jk->ik").unwrap();
        assert_eq!(ops[0], labels("i j"));
        assert_eq!(ops[1], labels("j k"));
        assert_eq!(out, labels("i k"));
    }

    #[test]
    fn parse_multi_char_spec() {
        let (ops, out) = parse_spec("s a, a h d -> s h d").unwrap();
        assert_eq!(ops[0], labels("s a"));
        assert_eq!(ops[1], labels("a h d"));
        assert_eq!(out, labels("s h d"));
    }

    #[test]
    fn parse_unary_spec() {
        let (ops, out) = parse_spec("ij->i").unwrap();
        assert_eq!(ops.len(), 1);
        assert_eq!(out, labels("i"));
    }

    #[test]
    fn reject_bad_specs() {
        assert!(parse_spec("ij,jk").is_err());
        assert!(parse_spec("i!j->ij").is_err());
        assert!(parse_spec("a,b,c->abc").is_err());
    }

    #[test]
    fn parse_program_matmul_chain() {
        let g = parse_program(
            r#"
            # (A x B) + (C x (D x E))
            input A [8, 8]
            input B [8, 8]
            input C [8, 8]
            input D [8, 8]
            input E [8, 8]
            AB  = einsum ij,jk->ik A B
            DE  = einsum jk,km->jm D E
            CDE = einsum ij,jm->im C DE
            Z   = ew add AB CDE
            "#,
        )
        .unwrap();
        assert_eq!(g.len(), 9);
        let z = g.by_name("Z").unwrap();
        assert_eq!(g.vertex(z).bound, vec![8, 8]);
    }

    #[test]
    fn parse_program_with_ops() {
        let g = parse_program(
            r#"
            input X [4, 8]
            input Y [8, 4]
            D = einsum ij,jk->ik X Y agg=max join=absdiff
            R = map relu D
            S = reduce sum ik->i R
            T = map scale:0.5 S
            "#,
        )
        .unwrap();
        let s = g.by_name("S").unwrap();
        assert_eq!(g.vertex(s).bound, vec![4]);
        let t = g.by_name("T").unwrap();
        assert_eq!(g.vertex(t).bound, vec![4]);
    }

    #[test]
    fn unknown_tensor_rejected() {
        assert!(parse_program("Z = map relu W").is_err());
    }

    #[test]
    fn bad_bound_rejected() {
        assert!(parse_program("input X 8,8").is_err());
        assert!(parse_program("input X [8, -1]").is_err());
    }
}

//! EinSum expressions — the paper's Section 3 in code.
//!
//! A binary EinSum in full generality (paper Eq. 2) is
//!
//! ```text
//!   forall l_Z in I(b_Z):  Z[l_Z] <- (+)_{l_agg} (x)(X[l_X], Y[l_Y])
//! ```
//!
//! where `(+)` is any commutative/associative aggregation ([`AggOp`]) and
//! `(x)` any scalar join function ([`JoinOp`]) — this is what makes it an
//! *extended* Einstein notation. Unary EinSums replace the join with a map
//! ([`UnaryOp`]) and optionally aggregate (e.g. `C_i <- max_j X_ij`).
//!
//! Broadcasts (output labels absent from all inputs) are rejected, as in
//! the paper ("we ignore broadcasts and focus on contractions").

use super::label::{
    all_distinct, concat, concat_dedup, difference, project, try_project, LabelList,
};
use crate::error::{Error, Result};

/// Commutative, associative aggregation operator `(+)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AggOp {
    Sum,
    Max,
    Min,
    Prod,
}

impl AggOp {
    /// Identity element of the aggregation.
    pub fn identity(&self) -> f32 {
        match self {
            AggOp::Sum => 0.0,
            AggOp::Max => f32::NEG_INFINITY,
            AggOp::Min => f32::INFINITY,
            AggOp::Prod => 1.0,
        }
    }

    /// Combine two partial aggregates.
    #[inline]
    pub fn combine(&self, a: f32, b: f32) -> f32 {
        match self {
            AggOp::Sum => a + b,
            AggOp::Max => a.max(b),
            AggOp::Min => a.min(b),
            AggOp::Prod => a * b,
        }
    }
}

/// Scalar join function `(x)` applied to matched pairs of input values.
///
/// `Mul` + `Sum` is a classic contraction; `SquaredDiff` + `Sum` computes
/// pairwise squared L2 distances; `AbsDiff` + `Max` computes the L-inf
/// distance — the paper's motivating examples.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum JoinOp {
    Mul,
    Add,
    Sub,
    Div,
    /// `(x - y)^2`
    SquaredDiff,
    /// `|x - y|`
    AbsDiff,
    /// `e^(x - y)` — used by the numerically-stable softmax macro.
    SubExp,
    Max,
    Min,
    /// Selects the right operand (`y`). Not user-facing: the autodiff
    /// module uses it to express broadcast ("spray `dZ` across the labels
    /// `l_X` has and `l_Z` lacks") without extending EinSum with true
    /// broadcasts, by joining against the primal `X`.
    Right,
}

impl Eq for JoinOp {}

impl std::hash::Hash for JoinOp {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        std::mem::discriminant(self).hash(state);
    }
}

impl JoinOp {
    /// Apply the scalar join function.
    #[inline]
    pub fn apply(&self, x: f32, y: f32) -> f32 {
        match self {
            JoinOp::Mul => x * y,
            JoinOp::Add => x + y,
            JoinOp::Sub => x - y,
            JoinOp::Div => x / y,
            JoinOp::SquaredDiff => (x - y) * (x - y),
            JoinOp::AbsDiff => (x - y).abs(),
            JoinOp::SubExp => (x - y).exp(),
            JoinOp::Max => x.max(y),
            JoinOp::Min => x.min(y),
            JoinOp::Right => y,
        }
    }
}

/// Scalar map function for unary EinSums.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UnaryOp {
    Identity,
    Exp,
    Neg,
    Relu,
    /// derivative of ReLU: 1 if x > 0 else 0
    ReluGrad,
    Recip,
    Sqrt,
    Rsqrt,
    Square,
    /// x * c
    Scale(f32),
    /// x + c
    AddConst(f32),
    /// SiLU / swish: x * sigmoid(x) — used by the LLaMA feed-forward block.
    Silu,
    Sigmoid,
    Tanh,
    Ln,
}

impl Eq for UnaryOp {}

impl std::hash::Hash for UnaryOp {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        std::mem::discriminant(self).hash(state);
        match self {
            UnaryOp::Scale(c) | UnaryOp::AddConst(c) => c.to_bits().hash(state),
            _ => {}
        }
    }
}

impl UnaryOp {
    /// Apply the scalar map.
    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            UnaryOp::Identity => x,
            UnaryOp::Exp => x.exp(),
            UnaryOp::Neg => -x,
            UnaryOp::Relu => x.max(0.0),
            UnaryOp::ReluGrad => {
                if x > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            UnaryOp::Recip => 1.0 / x,
            UnaryOp::Sqrt => x.sqrt(),
            UnaryOp::Rsqrt => 1.0 / x.sqrt(),
            UnaryOp::Square => x * x,
            UnaryOp::Scale(c) => x * c,
            UnaryOp::AddConst(c) => x + c,
            UnaryOp::Silu => x / (1.0 + (-x).exp()),
            UnaryOp::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            UnaryOp::Tanh => x.tanh(),
            UnaryOp::Ln => x.ln(),
        }
    }
}

/// An EinSum expression — the code run at an EinGraph vertex.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum EinSum {
    /// A graph input (leaf). `inputs` is empty iff the EinSum is `Input`.
    Input,
    /// `Z[l_z] <- agg_{l_x \ l_z} op(X[l_x])`: map, transpose (when `l_z`
    /// permutes `l_x`), and/or reduction (when labels are dropped).
    Unary {
        lx: LabelList,
        lz: LabelList,
        op: UnaryOp,
        agg: AggOp,
    },
    /// `Z[l_z] <- agg_{l_agg} join(X[l_x], Y[l_y])` (paper Eq. 2).
    Binary {
        lx: LabelList,
        ly: LabelList,
        lz: LabelList,
        join: JoinOp,
        agg: AggOp,
    },
}

impl EinSum {
    /// Classic contraction constructor: `Mul`/`Sum` over the given labels.
    pub fn contraction(lx: LabelList, ly: LabelList, lz: LabelList) -> EinSum {
        EinSum::Binary {
            lx,
            ly,
            lz,
            join: JoinOp::Mul,
            agg: AggOp::Sum,
        }
    }

    /// Elementwise binary op (no aggregation): `l_z` must equal the
    /// deduplicated join schema.
    pub fn elementwise(lx: LabelList, ly: LabelList, join: JoinOp) -> EinSum {
        let lz = concat_dedup(&lx, &ly);
        EinSum::Binary {
            lx,
            ly,
            lz,
            join,
            agg: AggOp::Sum,
        }
    }

    /// Unary map preserving shape.
    pub fn map(lx: LabelList, op: UnaryOp) -> EinSum {
        EinSum::Unary {
            lz: lx.clone(),
            lx,
            op,
            agg: AggOp::Sum,
        }
    }

    /// Unary reduction: aggregate out the labels of `lx` missing from `lz`.
    pub fn reduce(lx: LabelList, lz: LabelList, agg: AggOp) -> EinSum {
        EinSum::Unary {
            lx,
            lz,
            op: UnaryOp::Identity,
            agg,
        }
    }

    /// Number of tensor operands (0 for `Input`).
    pub fn arity(&self) -> usize {
        match self {
            EinSum::Input => 0,
            EinSum::Unary { .. } => 1,
            EinSum::Binary { .. } => 2,
        }
    }

    /// Output label list (`None` for inputs, which carry only a bound).
    pub fn lz(&self) -> Option<&LabelList> {
        match self {
            EinSum::Input => None,
            EinSum::Unary { lz, .. } => Some(lz),
            EinSum::Binary { lz, .. } => Some(lz),
        }
    }

    /// Operand label lists in order.
    pub fn operand_labels(&self) -> Vec<&LabelList> {
        match self {
            EinSum::Input => vec![],
            EinSum::Unary { lx, .. } => vec![lx],
            EinSum::Binary { lx, ly, .. } => vec![lx, ly],
        }
    }

    /// `l_XY`: concatenation of all operand label lists (duplicates kept).
    pub fn lxy(&self) -> LabelList {
        match self {
            EinSum::Input => vec![],
            EinSum::Unary { lx, .. } => lx.clone(),
            EinSum::Binary { lx, ly, .. } => concat(lx, ly),
        }
    }

    /// Unique labels across operands (the `D` "buckets" of Section 8.1 are
    /// these, with co-partitioned repeats collapsed).
    pub fn unique_labels(&self) -> LabelList {
        match self {
            EinSum::Input => vec![],
            EinSum::Unary { lx, .. } => lx.clone(),
            EinSum::Binary { lx, ly, .. } => concat_dedup(lx, ly),
        }
    }

    /// `l_agg`: labels aggregated out (in inputs, not in output).
    pub fn lagg(&self) -> LabelList {
        match self.lz() {
            None => vec![],
            Some(lz) => difference(&self.unique_labels(), lz),
        }
    }

    /// True if this is a contraction in the paper's sense: some labels are
    /// aggregated out.
    pub fn is_contraction(&self) -> bool {
        !self.lagg().is_empty()
    }

    /// True if this is an elementwise op (no aggregation).
    pub fn is_elementwise(&self) -> bool {
        self.arity() > 0 && self.lagg().is_empty()
    }

    /// Batched twin of this op: prepend the fresh label `b` to every
    /// operand and output label list. `b` must not already occur in the
    /// op (see [`crate::einsum::EinGraph::batched`], which picks one).
    ///
    /// Because the batch label lands first in every list, it is the first
    /// entry of the twin's `unique_labels` (so a solo partitioning vector
    /// extends to the twin by prepending the batch dim's split), and it
    /// appears in both operands *and* the output — the kernel engine
    /// classifies it as a BMM batch dim, leaving every other label's
    /// classification (and hence the solo op's dispatch path) unchanged.
    pub fn batched(&self, b: super::label::Label) -> EinSum {
        let pre = |l: &LabelList| -> LabelList {
            let mut v = Vec::with_capacity(l.len() + 1);
            v.push(b);
            v.extend_from_slice(l);
            v
        };
        match self {
            EinSum::Input => EinSum::Input,
            EinSum::Unary { lx, lz, op, agg } => EinSum::Unary {
                lx: pre(lx),
                lz: pre(lz),
                op: *op,
                agg: *agg,
            },
            EinSum::Binary {
                lx,
                ly,
                lz,
                join,
                agg,
            } => EinSum::Binary {
                lx: pre(lx),
                ly: pre(ly),
                lz: pre(lz),
                join: *join,
                agg: *agg,
            },
        }
    }

    /// Validate the expression against operand bounds and infer the output
    /// bound `b_Z = b_XY[l_Z; l_XY]`.
    ///
    /// Checks (per Section 3): no repeated labels *within* one operand; all
    /// output labels appear in some input (no broadcast); repeated labels
    /// across operands agree on their bound.
    pub fn infer_bound(&self, input_bounds: &[&[usize]]) -> Result<Vec<usize>> {
        if input_bounds.len() != self.arity() {
            return Err(Error::InvalidEinsum(format!(
                "expected {} operands, got {}",
                self.arity(),
                input_bounds.len()
            )));
        }
        match self {
            EinSum::Input => Err(Error::InvalidEinsum(
                "cannot infer bound of an Input vertex (bound is given, not derived)".into(),
            )),
            EinSum::Unary { lx, lz, .. } => {
                let bx = input_bounds[0];
                if bx.len() != lx.len() {
                    return Err(Error::InvalidEinsum(format!(
                        "rank mismatch: labels {lx:?} vs bound {bx:?}"
                    )));
                }
                if !all_distinct(lx) {
                    return Err(Error::InvalidEinsum(format!(
                        "repeated label within operand: {lx:?}"
                    )));
                }
                if !all_distinct(lz) {
                    return Err(Error::InvalidEinsum(format!(
                        "repeated label in output: {lz:?}"
                    )));
                }
                try_project(bx, lz, lx).ok_or_else(|| {
                    Error::InvalidEinsum(format!(
                        "output labels {lz:?} not all present in input {lx:?} (broadcast unsupported)"
                    ))
                })
            }
            EinSum::Binary { lx, ly, lz, .. } => {
                let (bx, by) = (input_bounds[0], input_bounds[1]);
                if bx.len() != lx.len() || by.len() != ly.len() {
                    return Err(Error::InvalidEinsum(format!(
                        "rank mismatch: {lx:?}/{bx:?} or {ly:?}/{by:?}"
                    )));
                }
                if !all_distinct(lx) || !all_distinct(ly) {
                    return Err(Error::InvalidEinsum(format!(
                        "repeated label within an operand: {lx:?} / {ly:?}"
                    )));
                }
                if !all_distinct(lz) {
                    return Err(Error::InvalidEinsum(format!(
                        "repeated label in output: {lz:?}"
                    )));
                }
                // Shared labels must agree on bounds.
                for (i, lab) in lx.iter().enumerate() {
                    if let Some(j) = ly.iter().position(|m| m == lab) {
                        if bx[i] != by[j] {
                            return Err(Error::InvalidEinsum(format!(
                                "label {lab} bound mismatch: {} vs {}",
                                bx[i], by[j]
                            )));
                        }
                    }
                }
                let bxy = [bx, by].concat();
                let lxy = self.lxy();
                try_project(&bxy, lz, &lxy).ok_or_else(|| {
                    Error::InvalidEinsum(format!(
                        "output labels {lz:?} not all present in inputs {lxy:?} (broadcast unsupported)"
                    ))
                })
            }
        }
    }

    /// `b_XY`: concatenated operand bounds (binary), or `b_X` (unary).
    pub fn bxy(&self, input_bounds: &[&[usize]]) -> Vec<usize> {
        input_bounds.concat()
    }

    /// Estimated floating-point operations to evaluate this EinSum on the
    /// given operand bounds (one op per join application + one per
    /// aggregation combine). Used for work-balance diagnostics; all
    /// decompositions of a vertex share this total (the paper's premise
    /// that only *communication* differentiates them).
    pub fn flops(&self, input_bounds: &[&[usize]]) -> Result<f64> {
        match self {
            EinSum::Input => Ok(0.0),
            EinSum::Unary { lx, .. } => {
                let bx = input_bounds[0];
                if bx.len() != lx.len() {
                    return Err(Error::InvalidEinsum("rank mismatch in flops".into()));
                }
                Ok(bx.iter().map(|&b| b as f64).product::<f64>() * 2.0)
            }
            EinSum::Binary { lz, .. } => {
                let bxy = self.bxy(input_bounds);
                let lxy = self.lxy();
                let uniq = self.unique_labels();
                let full: f64 = project(&bxy, &uniq, &lxy)
                    .iter()
                    .map(|&b| b as f64)
                    .product();
                let out: f64 = project(&bxy, lz, &lxy).iter().map(|&b| b as f64).product();
                // one join op per point in the full iteration space, plus
                // one combine per aggregated element
                Ok(full + (full - out).max(0.0))
            }
        }
    }
}

impl std::fmt::Display for EinSum {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        fn ll(l: &LabelList) -> String {
            l.iter()
                .map(|x| x.to_string())
                .collect::<Vec<_>>()
                .join(",")
        }
        match self {
            EinSum::Input => write!(f, "input"),
            EinSum::Unary { lx, lz, op, agg } => {
                if lz.len() < lx.len() {
                    write!(f, "Z[{}] <- {:?}_{{..}} {:?}(X[{}])", ll(lz), agg, op, ll(lx))
                } else {
                    write!(f, "Z[{}] <- {:?}(X[{}])", ll(lz), op, ll(lx))
                }
            }
            EinSum::Binary {
                lx,
                ly,
                lz,
                join,
                agg,
            } => write!(
                f,
                "Z[{}] <- {:?}_{{..}} {:?}(X[{}], Y[{}])",
                ll(lz),
                agg,
                join,
                ll(lx),
                ll(ly)
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::label::labels;

    fn matmul() -> EinSum {
        EinSum::contraction(labels("i j"), labels("j k"), labels("i k"))
    }

    #[test]
    fn matmul_bound_inference() {
        let e = matmul();
        let b = e.infer_bound(&[&[100, 200], &[200, 50]]).unwrap();
        assert_eq!(b, vec![100, 50]);
        assert_eq!(e.lagg(), labels("j"));
        assert!(e.is_contraction());
    }

    #[test]
    fn bound_mismatch_rejected() {
        let e = matmul();
        assert!(e.infer_bound(&[&[100, 200], &[300, 50]]).is_err());
    }

    #[test]
    fn broadcast_rejected() {
        let e = EinSum::contraction(labels("i j"), labels("j k"), labels("i k m"));
        assert!(e.infer_bound(&[&[4, 4], &[4, 4]]).is_err());
    }

    #[test]
    fn repeated_label_within_operand_rejected() {
        let e = EinSum::contraction(labels("i i"), labels("i k"), labels("k"));
        assert!(e.infer_bound(&[&[4, 4], &[4, 4]]).is_err());
    }

    #[test]
    fn paper_batch_matmul_example() {
        // Z_ik <- sum_{b,j} X_{i,j,b} Y_{j,b,k}; bX=[10,100,20], bY=[100,20,2000]
        let e = EinSum::contraction(labels("i j b"), labels("j b k"), labels("i k"));
        let bz = e.infer_bound(&[&[10, 100, 20], &[100, 20, 2000]]).unwrap();
        assert_eq!(bz, vec![10, 2000]);
        // l_agg = [b, j] per the paper (order: unique(lxy) \ lz = [j, b])
        let lagg = e.lagg();
        assert_eq!(lagg.len(), 2);
        assert!(lagg.contains(&labels("b")[0]) && lagg.contains(&labels("j")[0]));
        // bound vector for the aggregation is [20,100] (b then j) or [100,20]
        // in our (j,b) order — same multiset.
        let bxy = e.bxy(&[&[10, 100, 20], &[100, 20, 2000]]);
        let agg_bound = crate::einsum::label::project(&bxy, &lagg, &e.lxy());
        let mut sorted = agg_bound.clone();
        sorted.sort();
        assert_eq!(sorted, vec![20, 100]);
    }

    #[test]
    fn elementwise_classification() {
        let e = EinSum::elementwise(labels("i j"), labels("i j"), JoinOp::Add);
        assert!(e.is_elementwise());
        assert!(!e.is_contraction());
        assert_eq!(e.infer_bound(&[&[3, 4], &[3, 4]]).unwrap(), vec![3, 4]);
    }

    #[test]
    fn broadcast_join_divide_by_row() {
        // Y_ij <- E_ij / S_i  (the softmax normalization step)
        let e = EinSum::Binary {
            lx: labels("i j"),
            ly: labels("i"),
            lz: labels("i j"),
            join: JoinOp::Div,
            agg: AggOp::Sum,
        };
        assert_eq!(e.infer_bound(&[&[4, 8], &[4]]).unwrap(), vec![4, 8]);
        assert!(e.lagg().is_empty());
    }

    #[test]
    fn unary_reduce_max() {
        // C_i <- max_j X_ij
        let e = EinSum::reduce(labels("i j"), labels("i"), AggOp::Max);
        assert_eq!(e.infer_bound(&[&[4, 8]]).unwrap(), vec![4]);
        assert_eq!(e.lagg(), labels("j"));
    }

    #[test]
    fn unary_transpose() {
        let e = EinSum::reduce(labels("i j b"), labels("b i j"), AggOp::Sum);
        assert_eq!(e.infer_bound(&[&[10, 100, 20]]).unwrap(), vec![20, 10, 100]);
        assert!(e.lagg().is_empty());
    }

    #[test]
    fn scalar_ops() {
        assert_eq!(JoinOp::SquaredDiff.apply(3.0, 1.0), 4.0);
        assert_eq!(JoinOp::AbsDiff.apply(1.0, 3.0), 2.0);
        assert_eq!(AggOp::Max.combine(1.0, 2.0), 2.0);
        assert_eq!(AggOp::Sum.identity(), 0.0);
        assert_eq!(AggOp::Max.identity(), f32::NEG_INFINITY);
        assert!((UnaryOp::Silu.apply(0.0)).abs() < 1e-7);
        assert_eq!(UnaryOp::Scale(2.0).apply(3.0), 6.0);
        assert_eq!(UnaryOp::ReluGrad.apply(-1.0), 0.0);
    }

    #[test]
    fn flops_matmul() {
        let e = matmul();
        // 8x8x8: 512 joins + (512-64) combines
        let f = e.flops(&[&[8, 8], &[8, 8]]).unwrap();
        assert_eq!(f, 512.0 + 448.0);
    }

    #[test]
    fn display_is_readable() {
        let s = format!("{}", matmul());
        assert!(s.contains("Mul"));
    }
}

//! Hand-rolled CLI for the `eindecomp` binary (no external arg-parsing
//! crates in this container).
//!
//! ```text
//! eindecomp plan    --model chain|chain-skewed|ffnn|llama --p 16 [--scale N] [--compare]
//! eindecomp run     --model ...         --workers 8 [--backend native|auto]
//!                   [--exec steal|barrier] [--intra-op N] [--repeat N]
//!                   [--passes all|none|safe|<csv>]
//!                   [--topology flat|two-level|three-level]
//!                   [--inject-faults <spec>] [--max-retries N] [--deadline-ms N]
//!                   [--mem-budget-mb N]
//! eindecomp explain --model ...         [--workers N] [--p N] [--strategy S]
//!                   [--passes ...] [--topology ...] [--json]
//! eindecomp program --file prog.ein     [--p 8] [--run]
//! eindecomp help
//! ```

use crate::decomp::baselines::{assign, LabelRoles, Strategy};
use crate::einsum::parser::parse_program;
use crate::error::{Error, Result};
use crate::models::{ffnn, llama, matchain};
use crate::runtime::{Backend, MemoryBudget};
use crate::sim::network::{NetworkProfile, Topology};
use crate::tensor::Tensor;
use crate::tra::passes::PassSelector;
use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args {
            cmd: argv.first().cloned().unwrap_or_else(|| "help".into()),
            flags: HashMap::new(),
        };
        let mut i = 1;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| Error::Parse(format!("expected --flag, got {:?}", argv[i])))?;
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                a.flags.insert(k.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                a.flags.insert(k.to_string(), "true".into());
                i += 1;
            }
        }
        Ok(a)
    }

    pub fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    pub fn get_usize(&self, k: &str, default: usize) -> usize {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, k: &str) -> bool {
        matches!(self.get(k), Some("true") | Some("1") | Some("yes"))
    }
}

fn strategy_by_name(name: &str) -> Result<Strategy> {
    Ok(match name {
        "eindecomp" => Strategy::EinDecomp,
        "eindecomp-lin" => Strategy::EinDecompLinearized,
        "greedy" => Strategy::Greedy,
        "sqrt" => Strategy::Sqrt,
        "data-parallel" => Strategy::DataParallel,
        "megatron" => Strategy::Megatron,
        "sequence" => Strategy::Sequence,
        "attention" => Strategy::AttentionHead,
        other => {
            return Err(Error::Parse(format!(
                "unknown strategy {other:?} (try eindecomp, sqrt, data-parallel, megatron, sequence, attention, greedy)"
            )))
        }
    })
}

/// `--passes all|none|safe|<csv>` (defaults to the task-graph-neutral
/// `safe` pipeline when absent).
fn parse_passes(args: &Args) -> Result<PassSelector> {
    match args.get("passes") {
        Some(s) => s.parse(),
        None => Ok(PassSelector::default()),
    }
}

/// `--topology flat|two-level|three-level` (absent = the flat
/// [`NetworkProfile`] alone, byte-for-byte the seed model).
fn parse_topology(
    args: &Args,
    workers: usize,
    net: &NetworkProfile,
) -> Result<Option<Topology>> {
    Ok(match args.get("topology") {
        None => None,
        Some("flat") => Some(Topology::flat_of(net, workers)),
        Some("two-level") => Some(Topology::two_level_of(net, workers)),
        Some("three-level") => Some(Topology::three_level_of(net, workers)),
        Some(other) => {
            return Err(Error::Parse(format!(
                "unknown topology {other:?} (try flat, two-level, three-level)"
            )))
        }
    })
}

fn build_model(args: &Args) -> Result<crate::einsum::graph::EinGraph> {
    let scale = args.get_usize("scale", 64);
    match args.get("model").unwrap_or("chain") {
        "chain" => Ok(matchain::chain_graph(scale, false)?.graph),
        "chain-skewed" => Ok(matchain::chain_graph(scale.max(10), true)?.graph),
        "ffnn" => {
            let step = ffnn::ffnn_step(
                args.get_usize("batch", 128),
                args.get_usize("features", 1024),
                args.get_usize("hidden", 256),
                args.get_usize("classes", 64),
            )?;
            Ok(step.graph)
        }
        "llama" => {
            let cfg = llama::LlamaConfig::llama7b(
                args.get_usize("batch", 4),
                args.get_usize("seq", 1024),
            )
            .scaled(args.get_usize("shrink", 16), args.get_usize("layer-shrink", 8));
            Ok(llama::llama_graph(&cfg)?.graph)
        }
        other => Err(Error::Parse(format!("unknown model {other:?}"))),
    }
}

/// Run the CLI; returns the process exit code.
pub fn main_with_args(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.cmd.as_str() {
        "plan" => cmd_plan(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "explain" => cmd_explain(&args),
        "program" => cmd_program(&args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn cmd_plan(args: &Args) -> Result<()> {
    let g = build_model(args)?;
    let p = args.get_usize("p", 16);
    let roles = LabelRoles::by_convention();
    println!(
        "graph: {} vertices, {:.3} Gflop total",
        g.len(),
        g.total_flops() / 1e9
    );
    let strategies: Vec<Strategy> = if args.get_bool("compare") {
        vec![
            Strategy::EinDecomp,
            Strategy::Greedy,
            Strategy::Sqrt,
            Strategy::DataParallel,
            Strategy::Megatron,
            Strategy::Sequence,
            Strategy::AttentionHead,
        ]
    } else {
        vec![strategy_by_name(args.get("strategy").unwrap_or("eindecomp"))?]
    };
    println!("{:<16} {:>18} {:>10}", "strategy", "predicted floats", "plan ms");
    for s in strategies {
        let t0 = std::time::Instant::now();
        match assign(&g, &s, p, &roles) {
            Ok(plan) => println!(
                "{:<16} {:>18.0} {:>10.2}",
                s.name(),
                plan.predicted_cost,
                t0.elapsed().as_secs_f64() * 1e3
            ),
            Err(e) => println!("{:<16} failed: {e}", s.name()),
        }
    }
    Ok(())
}

/// `--mem-budget-mb N`: per-worker tile-residency budget in MiB.
/// 0 (or absent) means unlimited — the out-of-core machinery stays off.
fn parse_mem_budget(args: &Args) -> Result<Option<MemoryBudget>> {
    match args.get("mem-budget-mb") {
        None => Ok(None),
        Some(v) => {
            let mb: u64 = v
                .parse()
                .map_err(|_| Error::Parse(format!("--mem-budget-mb expects MiB, got {v:?}")))?;
            Ok(if mb == 0 {
                None
            } else {
                Some(MemoryBudget::per_worker_mb(mb))
            })
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    use super::driver::DriverConfig;
    use super::session::Session;
    let g = build_model(args)?;
    let workers = args.get_usize("workers", 4);
    let backend = match args.get("backend").unwrap_or("native") {
        "auto" => Backend::Auto,
        "pjrt" => Backend::PjrtStrict,
        _ => Backend::Native,
    };
    let exec_mode = match args.get("exec").unwrap_or("steal") {
        "barrier" | "level" => crate::sim::ExecMode::LevelBarrier,
        "steal" | "ws" => crate::sim::ExecMode::WorkStealing,
        other => {
            return Err(Error::Parse(format!(
                "unknown exec mode {other:?} (try steal or barrier)"
            )))
        }
    };
    let network = NetworkProfile::cpu_cluster();
    // --inject-faults task:<i>:transient[:<n>] | task:<i>:permanent |
    //                 seed:<u64>:<rate>   (comma-separated clauses)
    let faults = args
        .get("inject-faults")
        .map(|spec| spec.parse::<crate::sim::FaultPlan>())
        .transpose()?;
    let run_opts = crate::sim::RunOptions {
        // default mirrors RunOptions::default()
        max_retries: args.get_usize("max-retries", 3) as u32,
        deadline: args
            .get("deadline-ms")
            .map(|ms| -> Result<std::time::Duration> {
                let v: u64 = ms.parse().map_err(|_| {
                    Error::Parse(format!("--deadline-ms expects milliseconds, got {ms:?}"))
                })?;
                Ok(std::time::Duration::from_millis(v))
            })
            .transpose()?,
        ..Default::default()
    };
    let cfg = DriverConfig {
        workers,
        p: args.get_usize("p", workers),
        strategy: strategy_by_name(args.get("strategy").unwrap_or("eindecomp"))?,
        backend,
        topology: parse_topology(args, workers, &network)?,
        network,
        exec_mode,
        // 0 = match the executor's thread count (see DriverConfig docs).
        intra_op: args.get_usize("intra-op", 0),
        passes: parse_passes(args)?,
        faults,
        run_opts,
        mem_budget: parse_mem_budget(args)?,
        ..Default::default()
    };
    // Compile once (plan + lower + place), run `--repeat` many times: the
    // serving shape of the paper's pipeline. --repeat 1 is the legacy
    // one-shot behaviour.
    let repeat = args.get_usize("repeat", 1).max(1);
    let session = Session::new(cfg)?;
    let t0 = std::time::Instant::now();
    let exe = session.compile(&g)?;
    let compile_s = t0.elapsed().as_secs_f64();
    // random inputs for every graph input
    let mut inputs = HashMap::new();
    for (i, v) in g.inputs().into_iter().enumerate() {
        inputs.insert(v, Tensor::random(&g.vertex(v).bound, 100 + i as u64));
    }
    let (plan_s, lower_s) = exe.compile_times();
    let t1 = std::time::Instant::now();
    let mut last = None;
    let mut run_ms = Vec::with_capacity(repeat);
    for _ in 0..repeat {
        let t = std::time::Instant::now();
        last = Some(exe.run(&inputs)?);
        run_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let run_s = t1.elapsed().as_secs_f64();
    let (outs, rep) = last.expect("repeat >= 1");
    println!("strategy       : {}", rep.strategy);
    println!("plan cost      : {:.0} floats", rep.plan_cost);
    println!("plan time      : {:.2} ms", rep.plan_s * 1e3);
    println!(
        "compile        : {:.2} ms (plan {:.2} + lower {:.2}), provenance {}",
        compile_s * 1e3,
        plan_s * 1e3,
        lower_s * 1e3,
        exe.provenance()
    );
    if repeat > 1 {
        println!(
            "runs           : {repeat} x {:.2} ms avg -> {:.1} runs/s amortized (incl. compile)",
            run_s * 1e3 / repeat as f64,
            repeat as f64 / (compile_s + run_s)
        );
        println!(
            "run latency    : p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms (nearest-rank)",
            crate::util::percentile(&run_ms, 50.0),
            crate::util::percentile(&run_ms, 95.0),
            crate::util::percentile(&run_ms, 99.0),
        );
    }
    println!("report         : {}", rep.exec.summary());
    // Bitwise fingerprint of every output tensor — `scripts/chaos_smoke.sh`
    // diffs this between clean and fault-injected runs.
    println!(
        "output checksum: {:016x}",
        crate::serve::output_checksum(&outs)
    );
    println!("json           : {}", rep.to_json().render());
    Ok(())
}

/// `serve`: stand up a multi-tenant [`Server`](crate::serve::Server)
/// over the model and drive it with the closed-loop load generator —
/// the serving shape of the pipeline with admission control and
/// signature-keyed dynamic batching. `--verify` precomputes solo
/// reference checksums and fails the command unless the served outputs
/// are bitwise-identical and nothing was rejected.
fn cmd_serve(args: &Args) -> Result<()> {
    use super::driver::DriverConfig;
    use super::session::Session;
    use crate::serve::{output_checksum, run_load, LoadConfig, ServeConfig, Server};
    let g = build_model(args)?;
    let workers = args.get_usize("workers", 2);
    let cfg = DriverConfig {
        workers,
        p: args.get_usize("p", workers),
        strategy: strategy_by_name(args.get("strategy").unwrap_or("eindecomp"))?,
        ..Default::default()
    };
    let session = std::sync::Arc::new(Session::new(cfg)?);
    let max_batch = if args.get_bool("no-batch") {
        1
    } else {
        args.get_usize("max-batch", 8)
    };
    let window_ms = args.get_usize("batch-window-ms", 2) as u64;
    let serve_cfg = ServeConfig {
        serve_workers: args.get_usize("serve-workers", 2),
        max_batch,
        batch_window: std::time::Duration::from_millis(window_ms),
        max_queue_depth: args.get_usize("queue-depth", 1024),
        autostart: true,
    };
    let tenants = args.get_usize("tenants", 4).max(1);
    let requests = args.get_usize("requests", 64).max(1);
    let per_client = requests.div_ceil(tenants);
    // a small pool of distinct input seeds cycles across requests so
    // --verify can precompute one solo reference per seed
    let seeds: Vec<u64> = (0..8u64).map(|s| 1000 + s).collect();
    let seed_at = |c: usize, i: usize| seeds[(c * per_client + i) % seeds.len()];
    let verify = args.get_bool("verify");
    let mut expected = 0u64;
    if verify {
        let exe = session.compile(&g)?;
        let mut per_seed: HashMap<u64, u64> = HashMap::new();
        for c in 0..tenants {
            for i in 0..per_client {
                let seed = seed_at(c, i);
                let cs = match per_seed.get(&seed) {
                    Some(&cs) => cs,
                    None => {
                        let (outs, _) = exe.run(&model_inputs(&g, seed))?;
                        let cs = output_checksum(&outs);
                        per_seed.insert(seed, cs);
                        cs
                    }
                };
                expected ^= cs;
            }
        }
    }
    let server = Server::with_session(std::sync::Arc::clone(&session), serve_cfg);
    let load = LoadConfig {
        clients: tenants,
        requests_per_client: per_client,
    };
    let report = run_load(&server, &load, |c, i| {
        (
            format!("tenant-{c}"),
            g.clone(),
            model_inputs(&g, seed_at(c, i)),
        )
    })?;
    let stats = server.serve_stats();
    server.shutdown();
    println!(
        "served         : {}/{} requests from {tenants} tenants ({} rejected)",
        report.completed, report.requests, report.rejected
    );
    println!(
        "throughput     : {:.1} req/s over {:.2} s",
        report.req_per_s, report.elapsed_s
    );
    println!(
        "latency        : p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms",
        report.latency.p50_ms, report.latency.p95_ms, report.latency.p99_ms
    );
    println!(
        "batching       : {} coalesced executions covering {} requests (mean {:.2}, max {})",
        stats.batches, stats.batched_requests, report.mean_batched_with, report.max_batched_with
    );
    let cache = session.stats();
    println!(
        "compile cache  : {} compiles, {} hits, {} entries",
        cache.compiles, cache.hits, cache.entries
    );
    println!("output checksum: {:016x}", report.checksum);
    if verify {
        if report.rejected != 0 {
            return Err(Error::Exec(format!(
                "serve --verify: {} of {} requests rejected",
                report.rejected, report.requests
            )));
        }
        if report.checksum != expected {
            return Err(Error::Exec(format!(
                "serve --verify: served checksum {:016x} != solo reference {expected:016x}",
                report.checksum
            )));
        }
        println!(
            "verify         : ok ({} served outputs bitwise-identical to solo runs)",
            report.completed
        );
    }
    println!("json           : {}", report.to_json().render());
    Ok(())
}

/// Seeded random inputs for every graph input (seed varies per vertex
/// so twin inputs differ).
fn model_inputs(
    g: &crate::einsum::graph::EinGraph,
    seed: u64,
) -> HashMap<crate::einsum::graph::VertexId, Tensor> {
    g.inputs()
        .into_iter()
        .enumerate()
        .map(|(i, v)| (v, Tensor::random(&g.vertex(v).bound, seed + i as u64)))
        .collect()
}

/// `explain`: compile the model through the Session pipeline and print
/// the TRA program, the pass change log, and the modeled byte ledger —
/// the compiler mid-layer made visible without executing anything.
fn cmd_explain(args: &Args) -> Result<()> {
    use super::driver::DriverConfig;
    use super::session::Session;
    let g = build_model(args)?;
    let workers = args.get_usize("workers", 4);
    let network = NetworkProfile::cpu_cluster();
    let cfg = DriverConfig {
        workers,
        p: args.get_usize("p", workers),
        strategy: strategy_by_name(args.get("strategy").unwrap_or("eindecomp"))?,
        topology: parse_topology(args, workers, &network)?,
        network,
        passes: parse_passes(args)?,
        mem_budget: parse_mem_budget(args)?,
        ..Default::default()
    };
    let session = Session::new(cfg)?;
    let exe = session.compile(&g)?;
    let explain = session.explain(&exe);
    if args.get_bool("json") {
        println!("{}", explain.to_json().render());
    } else {
        print!("{explain}");
    }
    Ok(())
}

fn cmd_program(args: &Args) -> Result<()> {
    let path = args
        .get("file")
        .ok_or_else(|| Error::Parse("program needs --file".into()))?;
    let text = std::fs::read_to_string(path)?;
    let g = parse_program(&text)?;
    println!("parsed {} vertices", g.len());
    let p = args.get_usize("p", 8);
    let plan = assign(&g, &Strategy::EinDecomp, p, &LabelRoles::by_convention())?;
    println!("predicted cost: {:.0} floats", plan.predicted_cost);
    for vert in g.vertices() {
        if let Some(d) = plan.parts.get(&vert.id) {
            println!("  {:<24} d = {:?}", vert.name, d);
        }
    }
    if args.get_bool("run") {
        use super::driver::{Driver, DriverConfig};
        let driver = Driver::new(DriverConfig {
            workers: p,
            p,
            ..Default::default()
        })?;
        let mut inputs = HashMap::new();
        for (i, v) in g.inputs().into_iter().enumerate() {
            inputs.insert(v, Tensor::random(&g.vertex(v).bound, i as u64));
        }
        let (_, rep) = driver.run(&g, &inputs)?;
        println!("report: {}", rep.exec.summary());
    }
    Ok(())
}

fn print_help() {
    println!(
        r#"eindecomp — EinDecomp (PVLDB 2024) reproduction

USAGE:
  eindecomp plan    --model chain|chain-skewed|ffnn|llama [--p N] [--compare]
                    [--scale N] [--batch N] [--seq N] [--shrink N]
  eindecomp run     --model ... [--workers N] [--p N] [--strategy S]
                    [--backend native|auto|pjrt] [--exec steal|barrier]
                    [--intra-op N]   (kernel shard fan-out; 0 = threads)
                    [--repeat N]     (compile once, run N times; prints
                                      amortized serving throughput)
                    [--passes all|none|safe|<csv>]  (TRA-IR pass pipeline)
                    [--topology flat|two-level|three-level]
                                     (hierarchical interconnect: cost
                                      model, per-link byte ledger, and
                                      collective schedules)
                    [--inject-faults <spec>]
                                     (deterministic fault injection:
                                      comma-separated task:<i>:transient[:<n>],
                                      task:<i>:permanent, seed:<u64>:<rate>;
                                      recovery counters land in the report)
                    [--max-retries N]   (per-task retry budget, default 3)
                    [--deadline-ms N]   (whole-run deadline; exceeding it
                                         is a typed error with partial
                                         progress stats)
                    [--mem-budget-mb N] (per-worker tile-residency budget;
                                         cold tiles spill to disk and fault
                                         back on demand, outputs stay
                                         bitwise-identical; 0 = unlimited)
  eindecomp serve   --model ... [--workers N] [--p N] [--strategy S]
                    [--serve-workers N]  (serving pool threads, default 2)
                    [--tenants N]        (closed-loop clients, default 4)
                    [--requests N]       (total requests, default 64)
                    [--max-batch N]      (dynamic batching cap, default 8)
                    [--batch-window-ms N] [--queue-depth N] [--no-batch]
                    [--verify]           (fail unless served outputs are
                                          bitwise-identical to solo runs
                                          and nothing was rejected)
                    (multi-tenant serving: shared compile cache, fair
                     per-tenant queue, signature-keyed dynamic batching;
                     prints p50/p95/p99 latency and req/s)
  eindecomp explain --model ... [--workers N] [--p N] [--strategy S]
                    [--passes ...] [--topology ...] [--json]
                    [--mem-budget-mb N]  (reports whether the plan's peak
                                          residency fits the budget)
                    (print the TRA program, pass change log, modeled byte
                     ledger, and residency estimate of the compiled plan)
  eindecomp program --file prog.ein [--p N] [--run]

STRATEGIES: eindecomp, eindecomp-lin, greedy, sqrt, data-parallel,
            megatron, sequence, attention
PASSES:     propagate-partitions, elide-identity-repart, cse,
            alias-refinement-repart, fuse-epilogue, agg-tree,
            lower-collectives, dead-rel-elim
            ("safe" = the task-graph-neutral default)

Benches regenerating the paper's figures: `cargo bench` (see EXPERIMENTS.md)."#
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parse_flags() {
        let argv: Vec<String> = ["plan", "--model", "chain", "--p", "8", "--compare"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv).unwrap();
        assert_eq!(a.cmd, "plan");
        assert_eq!(a.get("model"), Some("chain"));
        assert_eq!(a.get_usize("p", 0), 8);
        assert!(a.get_bool("compare"));
    }

    #[test]
    fn bad_flag_rejected() {
        let argv: Vec<String> = ["plan", "model"].iter().map(|s| s.to_string()).collect();
        assert!(Args::parse(&argv).is_err());
    }

    #[test]
    fn plan_command_runs() {
        let argv: Vec<String> = ["plan", "--model", "chain", "--scale", "32", "--p", "4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        main_with_args(&argv).unwrap();
    }

    #[test]
    fn run_command_with_repeat() {
        let argv: Vec<String> = [
            "run", "--model", "chain", "--scale", "24", "--workers", "2", "--p", "2",
            "--repeat", "3",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        main_with_args(&argv).unwrap();
    }

    #[test]
    fn serve_command_verifies_bitwise_parity() {
        let argv: Vec<String> = [
            "serve", "--model", "chain", "--scale", "24", "--workers", "2", "--p", "2",
            "--serve-workers", "2", "--tenants", "3", "--requests", "9", "--verify",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        main_with_args(&argv).unwrap();
    }

    #[test]
    fn serve_command_no_batch_arm() {
        let argv: Vec<String> = [
            "serve", "--model", "chain", "--scale", "24", "--workers", "2", "--p", "2",
            "--serve-workers", "1", "--tenants", "2", "--requests", "4", "--no-batch",
            "--verify",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        main_with_args(&argv).unwrap();
    }

    #[test]
    fn explain_command_runs() {
        let variants: [&[&str]; 3] = [&[], &["--passes", "all"], &["--json"]];
        for extra in variants {
            let mut args = vec!["explain", "--model", "chain", "--scale", "24", "--p", "4"];
            args.extend_from_slice(extra);
            let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
            main_with_args(&argv).unwrap();
        }
    }

    #[test]
    fn run_command_with_topology() {
        let argv: Vec<String> = [
            "run", "--model", "chain", "--scale", "24", "--workers", "4", "--p", "4",
            "--topology", "three-level", "--passes", "all",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        main_with_args(&argv).unwrap();
    }

    #[test]
    fn explain_command_with_topology() {
        for topo in ["flat", "two-level", "three-level"] {
            let argv: Vec<String> = [
                "explain", "--model", "chain", "--scale", "24", "--p", "4", "--workers", "4",
                "--topology", topo,
            ]
            .iter()
            .map(|s| s.to_string())
            .collect();
            main_with_args(&argv).unwrap();
        }
    }

    #[test]
    fn run_command_with_fault_injection() {
        let argv: Vec<String> = [
            "run", "--model", "chain", "--scale", "24", "--workers", "2", "--p", "2",
            "--inject-faults", "task:3:transient,task:5:permanent", "--max-retries", "4",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        main_with_args(&argv).unwrap();
    }

    #[test]
    fn run_rejects_bad_fault_spec() {
        let argv: Vec<String> = [
            "run", "--model", "chain", "--scale", "24", "--workers", "2",
            "--inject-faults", "task:zero:transient",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let err = main_with_args(&argv).unwrap_err().to_string();
        assert!(err.contains("fault spec"), "{err}");
    }

    #[test]
    fn run_zero_deadline_reports_typed_timeout() {
        let argv: Vec<String> = [
            "run", "--model", "chain", "--scale", "24", "--workers", "2", "--deadline-ms", "0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let err = main_with_args(&argv).unwrap_err();
        assert!(err.is_deadline(), "{err}");
        assert!(err.to_string().contains("deadline exceeded"), "{err}");
    }

    #[test]
    fn mem_budget_flag_parses_and_zero_means_unlimited() {
        let parse = |argv: &[&str]| {
            let argv: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
            let a = Args::parse(&argv).unwrap();
            parse_mem_budget(&a)
        };
        assert_eq!(parse(&["run"]).unwrap(), None);
        assert_eq!(parse(&["run", "--mem-budget-mb", "0"]).unwrap(), None);
        let b = parse(&["run", "--mem-budget-mb", "64"]).unwrap().unwrap();
        assert_eq!(b.bytes_per_worker(), 64 << 20);
        let err = parse(&["run", "--mem-budget-mb", "lots"]).unwrap_err();
        assert!(err.to_string().contains("--mem-budget-mb"), "{err}");
    }

    #[test]
    fn run_and_explain_accept_mem_budget() {
        for cmd in [
            &["run", "--model", "chain", "--scale", "24", "--workers", "2", "--p", "2",
              "--mem-budget-mb", "1"][..],
            &["explain", "--model", "chain", "--scale", "24", "--p", "4",
              "--mem-budget-mb", "1"][..],
        ] {
            let argv: Vec<String> = cmd.iter().map(|s| s.to_string()).collect();
            main_with_args(&argv).unwrap();
        }
    }

    #[test]
    fn run_rejects_unknown_topology() {
        let argv: Vec<String> = [
            "run", "--model", "chain", "--scale", "24", "--workers", "2", "--topology", "torus",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let err = main_with_args(&argv).unwrap_err().to_string();
        assert!(err.contains("unknown topology"), "{err}");
    }

    #[test]
    fn run_rejects_unknown_passes() {
        let argv: Vec<String> = [
            "run", "--model", "chain", "--scale", "24", "--workers", "2", "--passes", "bogus",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let err = main_with_args(&argv).unwrap_err().to_string();
        assert!(err.contains("unknown pass"), "{err}");
        assert!(err.contains("agg-tree"), "error must list valid names: {err}");
    }

    #[test]
    fn run_rejects_duplicate_and_empty_pass_lists() {
        for bad in ["agg-tree,cse,agg-tree", "agg-tree,,cse"] {
            let argv: Vec<String> = [
                "run", "--model", "chain", "--scale", "24", "--workers", "2", "--passes", bad,
            ]
            .iter()
            .map(|s| s.to_string())
            .collect();
            let err = main_with_args(&argv).unwrap_err().to_string();
            assert!(
                err.contains("duplicate pass") || err.contains("empty pass name"),
                "--passes {bad}: {err}"
            );
        }
    }

    #[test]
    fn strategies_resolve() {
        for s in [
            "eindecomp",
            "sqrt",
            "data-parallel",
            "megatron",
            "sequence",
            "attention",
            "greedy",
        ] {
            strategy_by_name(s).unwrap();
        }
        assert!(strategy_by_name("nope").is_err());
    }
}

//! The legacy one-shot driver, now a thin shim over the compile-once /
//! run-many [`Session`](super::session::Session) API.
//!
//! **Soft-deprecated:** new code should use [`Session::compile`] +
//! [`Executable::run`](super::session::Executable::run), which plan and
//! lower once and then execute the frozen task graph per call.
//! `Driver::run` deliberately keeps the old per-call semantics —
//! re-planning and re-lowering on *every* invocation (via
//! [`Session::compile_fresh`]) — so existing sweeps and the serving
//! bench's cold baseline behave exactly as before.
//!
//! [`Session::compile`]: super::session::Session::compile
//! [`Session::compile_fresh`]: super::session::Session::compile_fresh

use super::session::Session;
use crate::decomp::baselines::{LabelRoles, Strategy};
use crate::decomp::Plan;
use crate::einsum::graph::{EinGraph, VertexId};
use crate::error::Result;
use crate::runtime::spill::MemoryBudget;
use crate::runtime::{Backend, DispatchEngine};
use crate::sim::cluster::{Cluster, ExecMode, ExecReport};
use crate::sim::faults::{FaultPlan, RunOptions};
use crate::sim::memory::{model_with_memory, MemoryConfig};
use crate::sim::network::{NetworkProfile, Topology};
use crate::taskgraph::placement::Policy;
use crate::tensor::Tensor;
use crate::tra::passes::PassSelector;
use crate::util::Json;
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;

/// Everything a run needs.
#[derive(Clone, Debug)]
pub struct DriverConfig {
    /// Simulated workers (devices).
    pub workers: usize,
    /// Planner kernel-call target (defaults to `workers`).
    pub p: usize,
    pub strategy: Strategy,
    pub backend: Backend,
    pub artifact_dir: PathBuf,
    pub network: NetworkProfile,
    pub placement: Policy,
    /// Host-thread scheduler for real execution (work stealing by
    /// default; [`ExecMode::LevelBarrier`] is the reference mode).
    pub exec_mode: ExecMode,
    /// Intra-op shard fan-out for real execution (`--intra-op` on the
    /// CLI): how many independent shards each kernel splits into so idle
    /// workers can steal them. `0` (default) matches the executor's
    /// thread count. Bitwise-neutral — see [`Cluster::with_intra_op`].
    pub intra_op: usize,
    /// TRA-IR pass pipeline run between planning and task emission
    /// (`--passes all|none|safe|<csv>` on the CLI; see
    /// [`crate::tra::passes`]). Defaults to the task-graph-neutral
    /// [`PassSelector::Safe`] set.
    pub passes: PassSelector,
    pub roles: LabelRoles,
    /// Hierarchical worker topology (`--topology` on the CLI). `None`
    /// (default) keeps the flat `network` profile — byte-for-byte the
    /// seed model. `Some` charges each modeled transfer at the link
    /// class of the two workers' lowest common group, reports
    /// [`ExecReport::bytes_by_link`], biases the planner's repartition
    /// costs toward topology-friendly layouts, and steers the
    /// `lower-collectives` gather schedule (ring on hierarchical
    /// topologies, tree on flat ones).
    pub topology: Option<Topology>,
    /// Deterministic fault plan (`--inject-faults` on the CLI). `None`
    /// (default) runs fault-free with a ledger byte-identical to the
    /// pre-recovery executor; `Some` makes the chosen tasks fail and
    /// exercises lineage-based recovery (see [`crate::sim::faults`]).
    pub faults: Option<FaultPlan>,
    /// Per-run execution options: retry budget, deadline, backoff shape,
    /// and opt-in non-finite input screening (`--max-retries` /
    /// `--deadline-ms` on the CLI).
    pub run_opts: RunOptions,
    /// Per-worker memory budget for real execution (`--mem-budget-mb` on
    /// the CLI). `None` (default) runs unbudgeted with residency
    /// tracking only; `Some` arms the out-of-core tile store — tiles
    /// beyond the budget spill to disk and fault back, with outputs
    /// bitwise-identical to the unbudgeted run (see
    /// [`crate::runtime::spill`]).
    pub mem_budget: Option<MemoryBudget>,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            workers: 4,
            p: 4,
            strategy: Strategy::EinDecomp,
            backend: Backend::Native,
            artifact_dir: PathBuf::from("artifacts"),
            network: NetworkProfile::cpu_cluster(),
            placement: Policy::LocalityGreedy,
            exec_mode: ExecMode::WorkStealing,
            intra_op: 0,
            passes: PassSelector::default(),
            roles: LabelRoles::by_convention(),
            topology: None,
            faults: None,
            run_opts: RunOptions::default(),
            mem_budget: None,
        }
    }
}

/// Where a run's plan came from — so sweeps stop conflating "planning was
/// free" (reused / cache hit) with "planning cost nothing" (a fresh plan
/// whose time simply was not measured).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlanProvenance {
    /// The planner ran for this very report; `plan_s` is its wall time.
    Planned,
    /// A caller-supplied plan was reused ([`Driver::run_with_plan`]);
    /// `plan_s` is 0.0 because planning happened (and was timed)
    /// elsewhere.
    Reused,
    /// Served from a [`Session`](super::session::Session) plan cache;
    /// `plan_s` reports the original compile's real planning time.
    CacheHit,
}

impl PlanProvenance {
    pub fn as_str(self) -> &'static str {
        match self {
            PlanProvenance::Planned => "planned",
            PlanProvenance::Reused => "reused",
            PlanProvenance::CacheHit => "cache_hit",
        }
    }
}

impl std::fmt::Display for PlanProvenance {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Report of one full run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub strategy: String,
    /// Planner's predicted communication bound (floats).
    pub plan_cost: f64,
    /// Planning wall time, seconds (the *original* compile's planning
    /// time when `provenance` is `CacheHit`; 0.0 only for `Reused`).
    pub plan_s: f64,
    /// Whether this run's plan was freshly planned, reused, or a cache
    /// hit.
    pub provenance: PlanProvenance,
    /// Names of the TRA-IR passes applied when this run's task graph was
    /// lowered (in pipeline order) — so sweeps can attribute wins to
    /// specific rewrites.
    pub passes: Vec<String>,
    /// How many requests shared the execution that produced this report.
    /// `1` for every direct run; the serving batcher sets the coalesced
    /// batch size when it splits one batched execution back into
    /// per-request reports (see [`crate::serve`]).
    pub batched_with: usize,
    /// Seconds the request waited in the serving queue (admission to
    /// execution start). `0.0` for direct runs that never queued.
    pub queue_wait_s: f64,
    pub exec: ExecReport,
}

impl RunReport {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("strategy".into(), Json::str(self.strategy.clone())),
            ("plan_cost_floats".into(), Json::num(self.plan_cost)),
            ("plan_s".into(), Json::num(self.plan_s)),
            (
                "plan_provenance".into(),
                Json::str(self.provenance.as_str()),
            ),
            (
                "passes".into(),
                Json::Arr(self.passes.iter().map(|p| Json::str(p.clone())).collect()),
            ),
            (
                "batched_with".into(),
                Json::num(self.batched_with as f64),
            ),
            ("queue_wait_s".into(), Json::num(self.queue_wait_s)),
            ("sim_makespan_s".into(), Json::num(self.exec.sim_makespan_s)),
            ("wall_s".into(), Json::num(self.exec.wall_s)),
            ("bytes_moved".into(), Json::num(self.exec.bytes_moved as f64)),
            ("bytes_input".into(), Json::num(self.exec.bytes_input as f64)),
            ("bytes_join".into(), Json::num(self.exec.bytes_join as f64)),
            ("bytes_agg".into(), Json::num(self.exec.bytes_agg as f64)),
            (
                "bytes_repart".into(),
                Json::num(self.exec.bytes_repart as f64),
            ),
            (
                "bytes_by_link".into(),
                Json::Obj(
                    self.exec
                        .bytes_by_link
                        .iter()
                        .map(|(name, b)| (name.clone(), Json::num(*b as f64)))
                        .collect(),
                ),
            ),
            ("kernel_calls".into(), Json::num(self.exec.kernel_calls as f64)),
            ("task_count".into(), Json::num(self.exec.tasks as f64)),
            ("efficiency".into(), Json::num(self.exec.efficiency())),
            (
                "faults_injected".into(),
                Json::num(self.exec.faults_injected as f64),
            ),
            ("retries".into(), Json::num(self.exec.retries as f64)),
            (
                "recomputed_tasks".into(),
                Json::num(self.exec.recomputed_tasks as f64),
            ),
            (
                "recovery_bytes".into(),
                Json::num(self.exec.recovery_bytes as f64),
            ),
            (
                "workers_lost".into(),
                Json::num(self.exec.workers_lost as f64),
            ),
            (
                "recovery_stall_s".into(),
                Json::num(self.exec.recovery_stall_s),
            ),
            (
                "recovery_by_link".into(),
                Json::Obj(
                    self.exec
                        .recovery_by_link
                        .iter()
                        .map(|(name, b)| (name.clone(), Json::num(*b as f64)))
                        .collect(),
                ),
            ),
            (
                "peak_resident_bytes".into(),
                Json::Arr(
                    self.exec
                        .peak_resident_bytes
                        .iter()
                        .map(|&b| Json::num(b as f64))
                        .collect(),
                ),
            ),
            ("spill_bytes".into(), Json::num(self.exec.spill_bytes as f64)),
            (
                "spill_faults".into(),
                Json::num(self.exec.spill_faults as f64),
            ),
            ("spill_stall_s".into(), Json::num(self.exec.spill_stall_s)),
        ])
    }
}

/// Orchestrates plan + execute for a fixed configuration. Thin wrapper
/// over an owned [`Session`] that preserves the legacy plan-every-call
/// behaviour; see the module docs.
pub struct Driver {
    session: Session,
}

impl Driver {
    pub fn new(cfg: DriverConfig) -> Result<Self> {
        Ok(Driver {
            session: Session::new(cfg)?,
        })
    }

    /// The configuration this driver (and its session) was built with.
    pub fn cfg(&self) -> &DriverConfig {
        &self.session.cfg
    }

    /// The underlying compile-once / run-many session.
    pub fn session(&self) -> &Session {
        &self.session
    }

    pub fn engine(&self) -> &DispatchEngine {
        self.session.engine()
    }

    pub fn cluster(&self) -> &Cluster {
        self.session.cluster()
    }

    /// Plan the graph with the configured strategy.
    pub fn plan(&self, g: &EinGraph) -> Result<(Plan, f64)> {
        self.session.plan(g)
    }

    /// Plan + execute for real; returns outputs keyed by vertex. Legacy
    /// semantics: re-plans and re-lowers on every call (use
    /// [`Session::compile`](super::session::Session::compile) to pay that
    /// cost once).
    pub fn run(
        &self,
        g: &EinGraph,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, RunReport)> {
        self.session.compile_fresh(g)?.run(inputs)
    }

    /// Run an already-computed plan (for strategy sweeps that reuse one
    /// planning pass). Reported with [`PlanProvenance::Reused`].
    pub fn run_with_plan(
        &self,
        g: &EinGraph,
        plan: &Plan,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, RunReport)> {
        self.session.execute_with_plan(g, plan, inputs)
    }

    /// Plan + model only (no tensors) — used at paper-scale shapes.
    pub fn dry_run(&self, g: &EinGraph) -> Result<RunReport> {
        let (plan, plan_s) = self.session.plan(g)?;
        let exec = self.session.cluster().dry_run(g, &plan)?;
        Ok(RunReport {
            strategy: plan.strategy.clone(),
            plan_cost: plan.predicted_cost,
            plan_s,
            provenance: PlanProvenance::Planned,
            passes: self.session.cluster().passes.manager().names(),
            batched_with: 1,
            queue_wait_s: 0.0,
            exec,
        })
    }

    /// Dry run under a device-memory budget (Experiment 4 / Fig. 11).
    pub fn dry_run_with_memory(
        &self,
        g: &EinGraph,
        mem: &MemoryConfig,
        weights: &HashSet<VertexId>,
    ) -> Result<RunReport> {
        let (plan, plan_s) = self.session.plan(g)?;
        let tg = self.session.cluster().lower(g, &plan)?;
        let cfg = self.cfg();
        let exec = model_with_memory(&tg, &cfg.network, cfg.workers, mem, weights);
        Ok(RunReport {
            strategy: plan.strategy.clone(),
            plan_cost: plan.predicted_cost,
            plan_s,
            provenance: PlanProvenance::Planned,
            passes: self.session.cluster().passes.manager().names(),
            batched_with: 1,
            queue_wait_s: 0.0,
            exec,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::matchain::{chain_graph, chain_inputs, chain_reference};

    #[test]
    fn driver_end_to_end_chain() {
        let chain = chain_graph(40, false).unwrap();
        let driver = Driver::new(DriverConfig::default()).unwrap();
        let inputs = chain_inputs(&chain, 1);
        let (outs, rep) = driver.run(&chain.graph, &inputs).unwrap();
        let want = chain_reference(&chain, &inputs).unwrap();
        assert!(outs[&chain.z].allclose(&want, 1e-3, 1e-4));
        assert!(rep.plan_cost > 0.0);
        assert!(rep.exec.kernel_calls >= 4);
        assert_eq!(rep.provenance, PlanProvenance::Planned);
        assert!(rep.plan_s > 0.0);
        // JSON report renders, including provenance
        let j = rep.to_json().render();
        assert!(j.contains("kernel_calls"));
        assert!(j.contains("\"plan_provenance\":\"planned\""));
    }

    #[test]
    fn run_with_plan_reports_reused_provenance() {
        let chain = chain_graph(32, false).unwrap();
        let driver = Driver::new(DriverConfig::default()).unwrap();
        let inputs = chain_inputs(&chain, 5);
        let (plan, plan_s) = driver.plan(&chain.graph).unwrap();
        assert!(plan_s > 0.0);
        let (outs, rep) = driver.run_with_plan(&chain.graph, &plan, &inputs).unwrap();
        let want = chain_reference(&chain, &inputs).unwrap();
        assert!(outs[&chain.z].allclose(&want, 1e-3, 1e-4));
        assert_eq!(rep.provenance, PlanProvenance::Reused);
        assert_eq!(rep.plan_s, 0.0);
        assert!(rep.to_json().render().contains("reused"));
    }

    #[test]
    fn exec_modes_agree_through_driver() {
        let chain = chain_graph(32, false).unwrap();
        let inputs = chain_inputs(&chain, 8);
        let want = chain_reference(&chain, &inputs).unwrap();
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            let driver = Driver::new(DriverConfig {
                exec_mode: mode,
                ..Default::default()
            })
            .unwrap();
            let (outs, _) = driver.run(&chain.graph, &inputs).unwrap();
            assert!(outs[&chain.z].allclose(&want, 1e-3, 1e-4), "{mode:?}");
        }
    }

    #[test]
    fn strategy_sweep_runs() {
        let chain = chain_graph(40, true).unwrap();
        let inputs = chain_inputs(&chain, 2);
        let want = chain_reference(&chain, &inputs).unwrap();
        for strategy in [Strategy::EinDecomp, Strategy::Sqrt, Strategy::Greedy] {
            let driver = Driver::new(DriverConfig {
                strategy: strategy.clone(),
                ..Default::default()
            })
            .unwrap();
            let (outs, _) = driver.run(&chain.graph, &inputs).unwrap();
            assert!(
                outs[&chain.z].allclose(&want, 1e-3, 1e-4),
                "{}",
                strategy.name()
            );
        }
    }
}

//! High-level driver tying the whole stack together: choose a
//! decomposition (EinDecomp or a baseline), lower to a task graph, place,
//! execute on the simulated cluster with the configured kernel backend,
//! and report. This is the entry point examples and benches use.

use crate::decomp::baselines::{assign, LabelRoles, Strategy};
use crate::decomp::Plan;
use crate::einsum::graph::{EinGraph, VertexId};
use crate::error::Result;
use crate::runtime::{Backend, DispatchEngine};
use crate::sim::cluster::{Cluster, ExecMode, ExecReport};
use crate::sim::memory::{model_with_memory, MemoryConfig};
use crate::sim::network::NetworkProfile;
use crate::taskgraph::placement::Policy;
use crate::tensor::Tensor;
use crate::util::Json;
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;

/// Everything a run needs.
#[derive(Clone, Debug)]
pub struct DriverConfig {
    /// Simulated workers (devices).
    pub workers: usize,
    /// Planner kernel-call target (defaults to `workers`).
    pub p: usize,
    pub strategy: Strategy,
    pub backend: Backend,
    pub artifact_dir: PathBuf,
    pub network: NetworkProfile,
    pub placement: Policy,
    /// Host-thread scheduler for real execution (work stealing by
    /// default; [`ExecMode::LevelBarrier`] is the reference mode).
    pub exec_mode: ExecMode,
    /// Intra-op shard fan-out for real execution (`--intra-op` on the
    /// CLI): how many independent shards each kernel splits into so idle
    /// workers can steal them. `0` (default) matches the executor's
    /// thread count. Bitwise-neutral — see [`Cluster::with_intra_op`].
    pub intra_op: usize,
    pub roles: LabelRoles,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            workers: 4,
            p: 4,
            strategy: Strategy::EinDecomp,
            backend: Backend::Native,
            artifact_dir: PathBuf::from("artifacts"),
            network: NetworkProfile::cpu_cluster(),
            placement: Policy::LocalityGreedy,
            exec_mode: ExecMode::WorkStealing,
            intra_op: 0,
            roles: LabelRoles::by_convention(),
        }
    }
}

/// Report of one full run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub strategy: String,
    /// Planner's predicted communication bound (floats).
    pub plan_cost: f64,
    /// Planning wall time, seconds.
    pub plan_s: f64,
    pub exec: ExecReport,
}

impl RunReport {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("strategy".into(), Json::str(self.strategy.clone())),
            ("plan_cost_floats".into(), Json::num(self.plan_cost)),
            ("plan_s".into(), Json::num(self.plan_s)),
            ("sim_makespan_s".into(), Json::num(self.exec.sim_makespan_s)),
            ("wall_s".into(), Json::num(self.exec.wall_s)),
            ("bytes_moved".into(), Json::num(self.exec.bytes_moved as f64)),
            ("bytes_join".into(), Json::num(self.exec.bytes_join as f64)),
            ("bytes_agg".into(), Json::num(self.exec.bytes_agg as f64)),
            (
                "bytes_repart".into(),
                Json::num(self.exec.bytes_repart as f64),
            ),
            ("kernel_calls".into(), Json::num(self.exec.kernel_calls as f64)),
            ("tasks".into(), Json::num(self.exec.tasks as f64)),
            ("efficiency".into(), Json::num(self.exec.efficiency())),
        ])
    }
}

/// Orchestrates plan + execute for a fixed configuration.
pub struct Driver {
    pub cfg: DriverConfig,
    engine: DispatchEngine,
    cluster: Cluster,
}

impl Driver {
    pub fn new(cfg: DriverConfig) -> Result<Self> {
        let engine = DispatchEngine::new(cfg.backend, &cfg.artifact_dir)?;
        let mut cluster = Cluster::new(cfg.workers, cfg.network.clone());
        cluster.placement = cfg.placement;
        cluster.exec_mode = cfg.exec_mode;
        cluster.intra_op = cfg.intra_op;
        Ok(Driver {
            cfg,
            engine,
            cluster,
        })
    }

    pub fn engine(&self) -> &DispatchEngine {
        &self.engine
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Plan the graph with the configured strategy.
    pub fn plan(&self, g: &EinGraph) -> Result<(Plan, f64)> {
        let t0 = std::time::Instant::now();
        let plan = assign(g, &self.cfg.strategy, self.cfg.p, &self.cfg.roles)?;
        Ok((plan, t0.elapsed().as_secs_f64()))
    }

    /// Plan + execute for real; returns outputs keyed by vertex.
    pub fn run(
        &self,
        g: &EinGraph,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, RunReport)> {
        let (plan, plan_s) = self.plan(g)?;
        let (outs, exec) = self.cluster.execute(g, &plan, &self.engine, inputs)?;
        Ok((
            outs,
            RunReport {
                strategy: plan.strategy.clone(),
                plan_cost: plan.predicted_cost,
                plan_s,
                exec,
            },
        ))
    }

    /// Run an already-computed plan (for strategy sweeps that reuse one
    /// planning pass).
    pub fn run_with_plan(
        &self,
        g: &EinGraph,
        plan: &Plan,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, RunReport)> {
        let (outs, exec) = self.cluster.execute(g, plan, &self.engine, inputs)?;
        Ok((
            outs,
            RunReport {
                strategy: plan.strategy.clone(),
                plan_cost: plan.predicted_cost,
                plan_s: 0.0,
                exec,
            },
        ))
    }

    /// Plan + model only (no tensors) — used at paper-scale shapes.
    pub fn dry_run(&self, g: &EinGraph) -> Result<RunReport> {
        let (plan, plan_s) = self.plan(g)?;
        let exec = self.cluster.dry_run(g, &plan)?;
        Ok(RunReport {
            strategy: plan.strategy.clone(),
            plan_cost: plan.predicted_cost,
            plan_s,
            exec,
        })
    }

    /// Dry run under a device-memory budget (Experiment 4 / Fig. 11).
    pub fn dry_run_with_memory(
        &self,
        g: &EinGraph,
        mem: &MemoryConfig,
        weights: &HashSet<VertexId>,
    ) -> Result<RunReport> {
        let (plan, plan_s) = self.plan(g)?;
        let tg = self.cluster.lower(g, &plan)?;
        let exec = model_with_memory(&tg, &self.cfg.network, self.cfg.workers, mem, weights);
        Ok(RunReport {
            strategy: plan.strategy.clone(),
            plan_cost: plan.predicted_cost,
            plan_s,
            exec,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::matchain::{chain_graph, chain_inputs, chain_reference};

    #[test]
    fn driver_end_to_end_chain() {
        let chain = chain_graph(40, false).unwrap();
        let driver = Driver::new(DriverConfig::default()).unwrap();
        let inputs = chain_inputs(&chain, 1);
        let (outs, rep) = driver.run(&chain.graph, &inputs).unwrap();
        let want = chain_reference(&chain, &inputs).unwrap();
        assert!(outs[&chain.z].allclose(&want, 1e-3, 1e-4));
        assert!(rep.plan_cost > 0.0);
        assert!(rep.exec.kernel_calls >= 4);
        // JSON report renders
        let j = rep.to_json().render();
        assert!(j.contains("kernel_calls"));
    }

    #[test]
    fn exec_modes_agree_through_driver() {
        let chain = chain_graph(32, false).unwrap();
        let inputs = chain_inputs(&chain, 8);
        let want = chain_reference(&chain, &inputs).unwrap();
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            let driver = Driver::new(DriverConfig {
                exec_mode: mode,
                ..Default::default()
            })
            .unwrap();
            let (outs, _) = driver.run(&chain.graph, &inputs).unwrap();
            assert!(outs[&chain.z].allclose(&want, 1e-3, 1e-4), "{mode:?}");
        }
    }

    #[test]
    fn strategy_sweep_runs() {
        let chain = chain_graph(40, true).unwrap();
        let inputs = chain_inputs(&chain, 2);
        let want = chain_reference(&chain, &inputs).unwrap();
        for strategy in [Strategy::EinDecomp, Strategy::Sqrt, Strategy::Greedy] {
            let driver = Driver::new(DriverConfig {
                strategy: strategy.clone(),
                ..Default::default()
            })
            .unwrap();
            let (outs, _) = driver.run(&chain.graph, &inputs).unwrap();
            assert!(
                outs[&chain.z].allclose(&want, 1e-3, 1e-4),
                "{}",
                strategy.name()
            );
        }
    }
}

//! Compile-once / run-many `Session` API.
//!
//! The paper's pipeline (EinSum spec → EinDecomp plan → TRA task graph →
//! execution) is declarative end to end, and planning is the expensive
//! step (Sections 5–8). Under a serving workload the same graph executes
//! for millions of requests, so that cost must be paid once, not per
//! call:
//!
//! * [`Session`] owns the kernel engine, the simulated cluster, and a
//!   **plan cache** keyed by the [`CanonSignature`] of the graph
//!   (deterministic label renaming + canonical vertex ordering + shape
//!   vector — see [`crate::einsum::canon`]), so `"ij,jk->ik"` and
//!   `"ab,bc->ac"` at equal shapes share one cache entry;
//! * [`Session::compile`] runs plan → lower → place exactly once per
//!   distinct signature and returns an [`Executable`];
//! * [`Executable::run`] executes the frozen, placed task graph with
//!   **zero** planner and **zero** lowering work per call, reusing the
//!   executor's buffer pools, and reports plan provenance
//!   ([`PlanProvenance::Planned`] on the compiling call,
//!   [`PlanProvenance::CacheHit`] afterwards) with the real `plan_s`
//!   either way.
//!
//! Graphs are built either directly ([`crate::einsum::graph::EinGraph`])
//! or through the lazy [`Expr`] frontend ([`Session::input`] /
//! [`Session::compile_expr`]).
//!
//! ```
//! use eindecomp::prelude::*;
//! use std::collections::HashMap;
//!
//! let session = Session::new(DriverConfig { workers: 2, p: 2, ..Default::default() })?;
//! let a = session.input("A", &[16, 16]);
//! let b = session.input("B", &[16, 16]);
//! let z = a.einsum("ij,jk->ik", &b)?;
//! let exe = session.compile_expr(&z)?;       // plan + lower + place, once
//! let mut inputs = HashMap::new();
//! inputs.insert(a.id(), Tensor::random(&[16, 16], 1));
//! inputs.insert(b.id(), Tensor::random(&[16, 16], 2));
//! let (outs, report) = exe.run(&inputs)?;    // zero planning per call
//! assert_eq!(outs[&z.id()].shape(), &[16, 16]);
//! assert_eq!(report.provenance, PlanProvenance::Planned);
//! assert_eq!(session.stats().misses, 1);
//! # Ok::<(), eindecomp::Error>(())
//! ```

use super::driver::{DriverConfig, PlanProvenance, RunReport};
use crate::decomp::baselines::{assign_on, Strategy};
use crate::decomp::Plan;
use crate::einsum::canon::{canonicalize, Canon, CanonSignature};
use crate::einsum::graph::{EinGraph, VertexId};
use crate::einsum::lazy::Expr;
use crate::error::{Error, LowerError, PlanError, Result};
use crate::runtime::DispatchEngine;
use crate::sim::cluster::Cluster;
use crate::sim::faults::RunOptions;
use crate::taskgraph::TaskGraph;
use crate::tensor::Tensor;
use crate::tra::passes::PassLog;
use crate::tra::program::TraProgram;
use crate::util::Json;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One compiled program: the graph snapshot, its plan, the lowered,
/// placed task graph, and the precomputed modeled-timeline report (a
/// pure function of the task graph — paid once here, not per request).
/// Shared (via `Arc`) between the cache and every `Executable` handed
/// out for it. `canon` is `None` for uncached [`Session::compile_fresh`]
/// artifacts, which never need a remap.
struct Artifact {
    graph: EinGraph,
    canon: Option<Canon>,
    plan: Plan,
    /// The optimized TRA program the task graph was emitted from, plus
    /// the per-pass change log — what `Session::explain` and
    /// `Executable::tra_program` expose (the applied-pass list is
    /// derived from the log, never stored separately).
    prog: TraProgram,
    pass_log: PassLog,
    tg: TaskGraph,
    model: crate::sim::cluster::ExecReport,
    plan_s: f64,
    lower_s: f64,
}

/// Plan-cache counters (monotonic over the session's lifetime).
///
/// The classification counters (`compiles` / `hits` / `misses` /
/// `raced`) are maintained under the cache lock, *at* the lookup and
/// publish decision points, so they stay mutually coherent under
/// concurrent [`Session::compile`] calls: `compiles == hits + misses`
/// holds in every snapshot, and `raced` accounts exactly for the misses
/// whose freshly-built artifact lost the publish race and was discarded.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// `compile()` / `compile_expr()` calls (cached path only).
    pub compiles: u64,
    /// Compiles served from the cache (no planning, no lowering).
    pub hits: u64,
    /// Compiles that had to plan + lower.
    pub misses: u64,
    /// Misses whose artifact lost the publish race to a concurrent
    /// compile of the same signature and was discarded in favor of the
    /// incumbent (the duplicate planning work is still counted in
    /// `misses` and `planner_runs`). Always `<= misses`.
    pub raced: u64,
    /// Total planner invocations (incl. `plan()` / `compile_fresh()`).
    pub planner_runs: u64,
    /// Total lower+place invocations.
    pub lower_runs: u64,
    /// Distinct signatures currently cached.
    pub entries: usize,
}

/// The plan cache proper: the artifact map together with the counters
/// that describe its decisions. One lock guards both, so a hit / miss /
/// raced classification can never be observed out of step with the map
/// state that caused it (see [`CacheStats`]).
#[derive(Default)]
struct PlanCache {
    map: HashMap<CanonSignature, Arc<Artifact>>,
    compiles: u64,
    hits: u64,
    misses: u64,
    raced: u64,
}

/// A long-lived execution context: engine + cluster + plan cache (+ the
/// staging graph of the lazy [`Expr`] frontend). See the module docs.
pub struct Session {
    pub cfg: DriverConfig,
    engine: Arc<DispatchEngine>,
    cluster: Cluster,
    cache: Mutex<PlanCache>,
    staging: Mutex<Arc<Mutex<EinGraph>>>,
    planner_runs: AtomicU64,
    lower_runs: AtomicU64,
}

impl Session {
    pub fn new(cfg: DriverConfig) -> Result<Self> {
        let engine = Arc::new(DispatchEngine::new(cfg.backend, &cfg.artifact_dir)?);
        let mut cluster = Cluster::new(cfg.workers, cfg.network.clone());
        cluster.placement = cfg.placement;
        cluster.exec_mode = cfg.exec_mode;
        cluster.intra_op = cfg.intra_op;
        cluster.passes = cfg.passes.clone();
        cluster.topology = cfg.topology.clone();
        cluster.faults = cfg.faults.clone().filter(|f| !f.is_empty());
        // zero ("unlimited") normalizes to None so `--mem-budget-mb 0`
        // runs the exact unbudgeted executor
        cluster.mem_budget = cfg.mem_budget.filter(|b| !b.is_unlimited());
        Ok(Session {
            cfg,
            engine,
            cluster,
            cache: Mutex::new(PlanCache::default()),
            staging: Mutex::new(Arc::new(Mutex::new(EinGraph::new()))),
            planner_runs: AtomicU64::new(0),
            lower_runs: AtomicU64::new(0),
        })
    }

    pub fn engine(&self) -> &DispatchEngine {
        &self.engine
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Plan-cache counters.
    pub fn stats(&self) -> CacheStats {
        let cache = self.cache.lock().unwrap();
        CacheStats {
            compiles: cache.compiles,
            hits: cache.hits,
            misses: cache.misses,
            raced: cache.raced,
            planner_runs: self.planner_runs.load(Ordering::Relaxed),
            lower_runs: self.lower_runs.load(Ordering::Relaxed),
            entries: cache.map.len(),
        }
    }

    /// Drop every cached artifact (counters are retained).
    pub fn clear_cache(&self) {
        self.cache.lock().unwrap().map.clear();
    }

    /// Start (or extend) the lazy program: declare an input tensor of the
    /// given shape and get back an [`Expr`] to chain einsums on. The
    /// program snapshot is taken by [`Self::compile_expr`].
    pub fn input(&self, name: &str, shape: &[usize]) -> Expr {
        let staging = self.staging.lock().unwrap().clone();
        Expr::input(&staging, name, shape)
    }

    /// Compile the lazy program `expr` belongs to — the **whole** staged
    /// graph, so sibling outputs created along the way are preserved and
    /// every staged input (used or not) becomes a required `run` input.
    /// If `expr` is from the session's current program, the staging slate
    /// is wiped so the next [`Self::input`] starts a fresh program.
    pub fn compile_expr(&self, expr: &Expr) -> Result<Executable> {
        let g = expr.graph();
        let exe = self.compile(&g)?;
        let mut staging = self.staging.lock().unwrap();
        let current: &Arc<Mutex<EinGraph>> = &staging;
        if Arc::ptr_eq(expr.builder(), current) {
            *staging = Arc::new(Mutex::new(EinGraph::new()));
        }
        Ok(exe)
    }

    /// Compile a graph: plan → lower → place exactly once per canonical
    /// signature. A canonically-equivalent graph (labels renamed, vertices
    /// reordered, same shapes) is a cache hit; the returned [`Executable`]
    /// transparently remaps the caller's vertex ids onto the cached
    /// artifact.
    pub fn compile(&self, g: &EinGraph) -> Result<Executable> {
        let canon = canonicalize(g);
        let key = self.cache_key(g, &canon);
        // Classify under the cache lock, at the lookup itself: a snapshot
        // of the counters can then never contradict the map state (a miss
        // that errors during build still counts as a miss — planning was
        // attempted for it).
        let cached = {
            let mut guard = self.cache.lock().unwrap();
            let cache = &mut *guard;
            cache.compiles += 1;
            match cache.map.get(&key) {
                Some(art) => {
                    cache.hits += 1;
                    Some(Arc::clone(art))
                }
                None => {
                    cache.misses += 1;
                    None
                }
            }
        };
        if let Some(art) = cached {
            return self.executable(art, &canon, PlanProvenance::CacheHit);
        }
        let art = self.build_artifact(g, Some(canon.clone()))?;
        // Re-check under the lock before publishing: a concurrent compile
        // of the same program may have landed first. Keep the incumbent so
        // every Executable of one signature shares one artifact; the loser
        // discards its build and is counted in `raced`.
        let art = {
            let mut guard = self.cache.lock().unwrap();
            let cache = &mut *guard;
            match cache.map.get(&key) {
                Some(existing) => {
                    cache.raced += 1;
                    Arc::clone(existing)
                }
                None => {
                    cache.map.insert(key, Arc::clone(&art));
                    art
                }
            }
        };
        self.executable(art, &canon, PlanProvenance::Planned)
    }

    /// The cache key for `g`: the canonical signature, extended with the
    /// concrete label names when the configured strategy plans by label
    /// *name* (role-driven baselines — their plans are not invariant
    /// under renaming, so renamed twins must not share an entry).
    fn cache_key(&self, g: &EinGraph, canon: &Canon) -> CanonSignature {
        let label_sensitive = matches!(
            self.cfg.strategy,
            Strategy::DataParallel
                | Strategy::Megatron
                | Strategy::Sequence
                | Strategy::AttentionHead
        );
        if label_sensitive {
            canon.named_signature(g)
        } else {
            canon.signature.clone()
        }
    }

    /// Compile without consulting or populating the cache — every call
    /// plans and lowers afresh (no canonicalization either: the result is
    /// used directly, so no remap can be needed). This is the legacy
    /// per-call semantics the [`super::driver::Driver`] shim preserves
    /// (and the baseline the serving bench measures the cache against).
    pub fn compile_fresh(&self, g: &EinGraph) -> Result<Executable> {
        let art = self.build_artifact(g, None)?;
        Ok(self.executable_identity(art, PlanProvenance::Planned))
    }

    /// Lower + place a caller-supplied plan for `g`, returning an
    /// uncached [`Executable`] in the caller's own vertex numbering (no
    /// canonicalization, no remap, no cache entry). The planner never
    /// runs: provenance is [`PlanProvenance::Reused`] with `plan_s = 0`.
    ///
    /// This is how the serving batcher materializes a batched twin: the
    /// twin's plan is *derived* from the solo artifact's (the batch dim
    /// prepended, unsplit — see [`crate::serve`]), so running the
    /// planner again would be both wasted work and a correctness risk
    /// (a different plan could change tile shapes and break the
    /// bitwise-equality contract with solo runs).
    pub fn compile_with_plan(&self, g: &EinGraph, plan: Plan) -> Result<Executable> {
        self.lower_runs.fetch_add(1, Ordering::Relaxed);
        let t1 = std::time::Instant::now();
        let (tg, prog, pass_log) = self.cluster.lower_explain(g, &plan).map_err(|e| match e {
            Error::LowerFailure(_) => e,
            other => Error::LowerFailure(LowerError {
                stage: "lower",
                detail: other.to_string(),
            }),
        })?;
        let lower_s = t1.elapsed().as_secs_f64();
        let model = self.cluster.model(&tg);
        let art = Arc::new(Artifact {
            graph: g.clone(),
            canon: None,
            plan,
            prog,
            pass_log,
            tg,
            model,
            plan_s: 0.0,
            lower_s,
        });
        Ok(self.executable_identity(art, PlanProvenance::Reused))
    }

    /// Convenience: compile (through the cache) and run once.
    pub fn run(
        &self,
        g: &EinGraph,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, RunReport)> {
        self.compile(g)?.run(inputs)
    }

    /// Plan only (no lowering, no cache) — wall time included.
    pub fn plan(&self, g: &EinGraph) -> Result<(Plan, f64)> {
        self.planner_runs.fetch_add(1, Ordering::Relaxed);
        let t0 = std::time::Instant::now();
        let plan = self.plan_typed(g)?;
        Ok((plan, t0.elapsed().as_secs_f64()))
    }

    /// Run the configured planner, wrapping any failure into the typed
    /// [`Error::PlanFailure`] surface (strategy tag + underlying detail).
    fn plan_typed(&self, g: &EinGraph) -> Result<Plan> {
        assign_on(
            g,
            &self.cfg.strategy,
            self.cfg.p,
            &self.cfg.roles,
            self.cfg.topology.as_ref(),
        )
        .map_err(|e| match e {
            Error::PlanFailure(_) => e,
            other => Error::PlanFailure(PlanError {
                strategy: self.cfg.strategy.name().to_string(),
                detail: other.to_string(),
            }),
        })
    }

    /// Execute a caller-supplied plan (strategy sweeps that reuse one
    /// planning pass). Lowers per call; reports
    /// [`PlanProvenance::Reused`] with `plan_s = 0.0` since planning
    /// genuinely happened elsewhere.
    pub fn execute_with_plan(
        &self,
        g: &EinGraph,
        plan: &Plan,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, RunReport)> {
        self.lower_runs.fetch_add(1, Ordering::Relaxed);
        let (outs, exec) = self.cluster.execute(g, plan, self.engine.as_ref(), inputs)?;
        Ok((
            outs,
            RunReport {
                strategy: plan.strategy.clone(),
                plan_cost: plan.predicted_cost,
                plan_s: 0.0,
                provenance: PlanProvenance::Reused,
                passes: self.cluster.passes.manager().names(),
                batched_with: 1,
                queue_wait_s: 0.0,
                exec,
            },
        ))
    }

    /// Explain a compiled [`Executable`]: the optimized TRA program
    /// listing (with relation schemas), the per-pass change log, and the
    /// modeled per-[`TransferClass`](crate::taskgraph::TransferClass)
    /// byte totals of its frozen task graph. Pretty-print with
    /// [`Explain::render`] (the CLI `explain` subcommand) or serialize
    /// with [`Explain::to_json`].
    pub fn explain(&self, exe: &Executable) -> Explain {
        let art = &exe.art;
        let residency = art.prog.residency_stats();
        let mem_budget_bytes = self
            .cluster
            .mem_budget
            .map(|b| b.bytes_per_worker())
            .unwrap_or(0);
        Explain {
            residency_peak_bytes: residency.peak_bytes,
            residency_max_task_bytes: residency.max_task_bytes,
            mem_budget_bytes,
            residency_fits_budget: self
                .cluster
                .mem_budget
                .map(|b| residency.fits(b.bytes_per_worker(), self.cfg.workers)),
            strategy: art.plan.strategy.clone(),
            plan_cost: art.plan.predicted_cost,
            program: art.prog.render(),
            pass_log: art.pass_log.clone(),
            passes: art.pass_log.applied(),
            tasks: art.model.tasks,
            kernel_calls: art.model.kernel_calls,
            bytes_input: art.model.bytes_input,
            bytes_join: art.model.bytes_join,
            bytes_agg: art.model.bytes_agg,
            bytes_repart: art.model.bytes_repart,
            bytes_by_link: art.model.bytes_by_link.clone(),
            fault_plan: self
                .cluster
                .faults
                .as_ref()
                .map(|f| f.to_string())
                .unwrap_or_else(|| "none".to_string()),
            faults_injected: art.model.faults_injected,
            retries: art.model.retries,
            recomputed_tasks: art.model.recomputed_tasks,
            recovery_bytes: art.model.recovery_bytes,
        }
    }

    fn build_artifact(&self, g: &EinGraph, canon: Option<Canon>) -> Result<Arc<Artifact>> {
        self.planner_runs.fetch_add(1, Ordering::Relaxed);
        let t0 = std::time::Instant::now();
        let plan = self.plan_typed(g)?;
        let plan_s = t0.elapsed().as_secs_f64();
        self.lower_runs.fetch_add(1, Ordering::Relaxed);
        let t1 = std::time::Instant::now();
        let (tg, prog, pass_log) = self.cluster.lower_explain(g, &plan).map_err(|e| match e {
            Error::LowerFailure(_) => e,
            other => Error::LowerFailure(LowerError {
                stage: "lower",
                detail: other.to_string(),
            }),
        })?;
        let lower_s = t1.elapsed().as_secs_f64();
        let model = self.cluster.model(&tg);
        Ok(Arc::new(Artifact {
            graph: g.clone(),
            canon,
            plan,
            prog,
            pass_log,
            tg,
            model,
            plan_s,
            lower_s,
        }))
    }

    /// Wrap an artifact whose vertex numbering IS the caller's (fresh
    /// compiles): no remap.
    fn executable_identity(&self, art: Arc<Artifact>, provenance: PlanProvenance) -> Executable {
        Executable {
            art,
            engine: Arc::clone(&self.engine),
            cluster: self.cluster.clone(),
            remap: None,
            provenance,
            run_opts: self.cfg.run_opts,
        }
    }

    /// Wrap an artifact for a presented graph whose canonicalization is
    /// `presented`: compute the vertex remap between the presented and
    /// stored numbering (identity remaps are elided).
    fn executable(
        &self,
        art: Arc<Artifact>,
        presented: &Canon,
        provenance: PlanProvenance,
    ) -> Result<Executable> {
        if art.canon.is_none() {
            // fresh artifacts are never cached, so a presented canon only
            // ever meets a canonicalized artifact; fall back defensively
            return Ok(self.executable_identity(art, provenance));
        }
        let stored = art.canon.as_ref().expect("checked above");
        if presented.canon_of.len() != stored.canon_of.len() {
            return Err(Error::InvalidGraph(
                "signature collision: cached graph has different size (internal)".into(),
            ));
        }
        let n = presented.canon_of.len();
        let mut to_stored = Vec::with_capacity(n);
        let mut identity = true;
        for v in 0..n {
            let s = stored.order[presented.canon_of[v]];
            identity &= s.0 == v;
            to_stored.push(s);
        }
        let remap = if identity {
            None
        } else {
            let mut to_presented = vec![VertexId(0); n];
            for (v, &s) in to_stored.iter().enumerate() {
                to_presented[s.0] = VertexId(v);
            }
            Some(Remap {
                to_stored,
                to_presented,
            })
        };
        Ok(Executable {
            art,
            engine: Arc::clone(&self.engine),
            cluster: self.cluster.clone(),
            remap,
            provenance,
            run_opts: self.cfg.run_opts,
        })
    }
}

/// Vertex-id translation between a presented graph and the cached
/// artifact it hit (both directions; indices are vertex ids).
struct Remap {
    to_stored: Vec<VertexId>,
    to_presented: Vec<VertexId>,
}

/// A compiled program: frozen plan + placed task graph, ready to execute
/// any number of times with zero planner/lowering work per call. Cheap to
/// clone conceptually — obtain more handles by calling
/// [`Session::compile`] again (a cache hit).
pub struct Executable {
    art: Arc<Artifact>,
    engine: Arc<DispatchEngine>,
    cluster: Cluster,
    remap: Option<Remap>,
    provenance: PlanProvenance,
    run_opts: RunOptions,
}

impl Executable {
    /// Execute the frozen task graph on `inputs` (keyed by the vertex ids
    /// of the graph this executable was compiled from — remapping onto a
    /// cached twin is handled internally, and tensor remap cost is O(1)
    /// per input thanks to `Arc`-backed buffers). Outputs come back under
    /// the caller's vertex ids. Bitwise-deterministic across calls.
    pub fn run(
        &self,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, RunReport)> {
        self.run_with(inputs, &self.run_opts)
    }

    /// [`run`](Self::run) with explicit per-call [`RunOptions`] (retry
    /// budget, deadline, non-finite input screening), overriding the
    /// session-level `DriverConfig::run_opts` for this call only. A run
    /// that exceeds `opts.deadline` returns a typed
    /// [`ExecCause::DeadlineExceeded`](crate::error::ExecCause) error
    /// carrying partial-progress stats.
    pub fn run_with(
        &self,
        inputs: &HashMap<VertexId, Tensor>,
        opts: &RunOptions,
    ) -> Result<(HashMap<VertexId, Tensor>, RunReport)> {
        let mapped;
        let effective: &HashMap<VertexId, Tensor> = match &self.remap {
            None => inputs,
            Some(r) => {
                let mut m = HashMap::with_capacity(inputs.len());
                for (vid, t) in inputs {
                    // Extraneous ids are ignored, matching the identity
                    // path (the executor checks *required* inputs and
                    // errors, by name, on any that are missing).
                    if let Some(&s) = r.to_stored.get(vid.0) {
                        m.insert(s, t.clone());
                    }
                }
                mapped = m;
                &mapped
            }
        };
        let (outs, exec) = self.cluster.run_lowered_modeled_opts(
            &self.art.graph,
            &self.art.plan,
            &self.art.tg,
            &self.art.model,
            self.engine.as_ref(),
            effective,
            opts,
        )?;
        let outs = match &self.remap {
            None => outs,
            Some(r) => outs
                .into_iter()
                .map(|(vid, t)| (r.to_presented[vid.0], t))
                .collect(),
        };
        Ok((
            outs,
            RunReport {
                strategy: self.art.plan.strategy.clone(),
                plan_cost: self.art.plan.predicted_cost,
                plan_s: self.art.plan_s,
                provenance: self.provenance,
                passes: self.art.pass_log.applied(),
                batched_with: 1,
                queue_wait_s: 0.0,
                exec,
            },
        ))
    }

    /// The frozen plan.
    ///
    /// **Numbering caveat:** on a [`PlanProvenance::CacheHit`], this plan
    /// (like [`graph`](Self::graph) / [`task_graph`](Self::task_graph))
    /// uses the *originally compiled* twin's vertex ids, which may differ
    /// from the graph you presented. Only [`run`](Self::run) translates
    /// ids; don't index these artifacts with presented-graph ids unless
    /// `provenance()` is `Planned`.
    pub fn plan(&self) -> &Plan {
        &self.art.plan
    }

    /// The compiled graph snapshot — the cached twin's numbering on a
    /// cache hit (see [`plan`](Self::plan) for the caveat).
    pub fn graph(&self) -> &EinGraph {
        &self.art.graph
    }

    /// The lowered, placed task graph this executable replays (cached
    /// twin's numbering on a hit; see [`plan`](Self::plan)).
    pub fn task_graph(&self) -> &TaskGraph {
        &self.art.tg
    }

    /// The optimized TRA program the task graph was emitted from — the
    /// Eq.-5 relational form of the compiled computation, after the
    /// session's pass pipeline (cached twin's numbering on a hit; see
    /// [`plan`](Self::plan)).
    pub fn tra_program(&self) -> &TraProgram {
        &self.art.prog
    }

    /// Per-pass change log of the compile that produced this artifact.
    pub fn pass_log(&self) -> &PassLog {
        &self.art.pass_log
    }

    /// Names of the passes applied at compile, in pipeline order
    /// (derived from [`pass_log`](Self::pass_log)).
    pub fn passes(&self) -> Vec<String> {
        self.art.pass_log.applied()
    }

    /// Canonical signature of the compiled program (computed on demand
    /// for [`Session::compile_fresh`] artifacts, which skip
    /// canonicalization on their hot path).
    pub fn signature(&self) -> CanonSignature {
        match &self.art.canon {
            Some(c) => c.signature.clone(),
            None => canonicalize(&self.art.graph).signature,
        }
    }

    /// How this executable's plan came to be: freshly planned or served
    /// from the session's plan cache.
    pub fn provenance(&self) -> PlanProvenance {
        self.provenance
    }

    /// Translate a vertex id of the graph this executable was compiled
    /// from into the stored artifact's numbering — the numbering
    /// [`plan`](Self::plan) / [`graph`](Self::graph) /
    /// [`task_graph`](Self::task_graph) use. Identity unless this handle
    /// came from a cache hit on a canonically-equivalent twin. Ids
    /// outside the graph come back unchanged.
    pub fn to_stored(&self, v: VertexId) -> VertexId {
        match &self.remap {
            None => v,
            Some(r) => r.to_stored.get(v.0).copied().unwrap_or(v),
        }
    }

    /// Inverse of [`to_stored`](Self::to_stored): stored numbering back
    /// to the caller's.
    pub fn to_presented(&self, v: VertexId) -> VertexId {
        match &self.remap {
            None => v,
            Some(r) => r.to_presented.get(v.0).copied().unwrap_or(v),
        }
    }

    /// Opaque identity of the shared compiled artifact: two executables
    /// from the same session compare equal here iff they share one
    /// artifact (one plan, one placed task graph, one stored numbering).
    /// The serving batcher uses this as its coalescing key — it is
    /// exactly "same plan-cache entry", which the session already keys
    /// by canonical (or named, for label-sensitive strategies)
    /// signature. Not meaningful across sessions or after every handle
    /// to the artifact is dropped.
    pub fn artifact_key(&self) -> usize {
        Arc::as_ptr(&self.art) as usize
    }

    /// `(plan_s, lower_s)` wall-clock of the original compile.
    pub fn compile_times(&self) -> (f64, f64) {
        (self.art.plan_s, self.art.lower_s)
    }
}

/// What [`Session::explain`] reports about a compiled program: the
/// optimized TRA program listing, the pass pipeline's change log, and
/// the modeled byte ledger per transfer class.
#[derive(Clone, Debug)]
pub struct Explain {
    pub strategy: String,
    /// Planner's predicted communication bound (floats).
    pub plan_cost: f64,
    /// Pretty-printed TRA program (one node per line, with schemas).
    pub program: String,
    pub pass_log: PassLog,
    /// Passes applied, in pipeline order.
    pub passes: Vec<String>,
    pub tasks: usize,
    pub kernel_calls: usize,
    /// Modeled cross-worker bytes by transfer class.
    pub bytes_input: u64,
    pub bytes_join: u64,
    pub bytes_agg: u64,
    pub bytes_repart: u64,
    /// Modeled cross-worker bytes by link class, innermost first —
    /// `[("flat", total)]` when the session has no
    /// [`Topology`](crate::sim::network::Topology) configured.
    pub bytes_by_link: Vec<(String, u64)>,
    /// The session's configured fault plan, in canonical spec form
    /// (`"none"` when fault-free). The compile-time model is always
    /// fault-free; injection happens at run time.
    pub fault_plan: String,
    /// Recovery counters of the artifact's modeled report — zero by
    /// construction (the model never injects); real runs report theirs in
    /// [`RunReport`](super::driver::RunReport).
    pub faults_injected: u64,
    pub retries: u64,
    pub recomputed_tasks: u64,
    pub recovery_bytes: u64,
    /// Planner-side peak-residency estimate over the whole cluster (see
    /// [`crate::tra::program::ResidencyStats`]).
    pub residency_peak_bytes: u64,
    /// Upper bound on any single task's working set, bytes.
    pub residency_max_task_bytes: u64,
    /// The session's per-worker memory budget in bytes (`0` =
    /// unlimited).
    pub mem_budget_bytes: u64,
    /// Whether the plan's estimated residency fits the budget without
    /// spilling (`None` when unbudgeted). `Some(false)` still runs —
    /// out-of-core, bitwise-identical — as long as the single-task
    /// bound fits.
    pub residency_fits_budget: Option<bool>,
}

impl Explain {
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "strategy: {} (predicted {:.0} floats moved)\n",
            self.strategy, self.plan_cost
        ));
        s.push_str(&self.program);
        s.push_str(&self.pass_log.render());
        s.push_str(&format!(
            "task graph: {} tasks ({} kernel calls)\n",
            self.tasks, self.kernel_calls
        ));
        s.push_str(&format!(
            "modeled bytes: input {} | join {} | agg {} | repart {}\n",
            self.bytes_input, self.bytes_join, self.bytes_agg, self.bytes_repart
        ));
        if !self.bytes_by_link.is_empty() {
            let per_link: Vec<String> = self
                .bytes_by_link
                .iter()
                .map(|(name, b)| format!("{name} {b}"))
                .collect();
            s.push_str(&format!("modeled bytes by link: {}\n", per_link.join(" | ")));
        }
        s.push_str(&format!(
            "residency: peak {} B | max task {} B | budget {}\n",
            self.residency_peak_bytes,
            self.residency_max_task_bytes,
            match self.residency_fits_budget {
                None => "unlimited".to_string(),
                Some(true) => format!("{} B/worker (fits)", self.mem_budget_bytes),
                Some(false) => {
                    format!("{} B/worker (spills out-of-core)", self.mem_budget_bytes)
                }
            }
        ));
        s.push_str(&format!("fault plan: {}\n", self.fault_plan));
        if self.faults_injected > 0 {
            s.push_str(&format!(
                "recovery: faults {} | retries {} | recomputed {} | bytes {}\n",
                self.faults_injected, self.retries, self.recomputed_tasks, self.recovery_bytes
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("strategy".into(), Json::str(self.strategy.clone())),
            ("plan_cost_floats".into(), Json::num(self.plan_cost)),
            ("program".into(), Json::str(self.program.clone())),
            ("passes".into(), self.pass_log.to_json()),
            ("tasks".into(), Json::num(self.tasks as f64)),
            (
                "kernel_calls".into(),
                Json::num(self.kernel_calls as f64),
            ),
            ("bytes_input".into(), Json::num(self.bytes_input as f64)),
            ("bytes_join".into(), Json::num(self.bytes_join as f64)),
            ("bytes_agg".into(), Json::num(self.bytes_agg as f64)),
            (
                "bytes_repart".into(),
                Json::num(self.bytes_repart as f64),
            ),
            (
                "bytes_by_link".into(),
                Json::Obj(
                    self.bytes_by_link
                        .iter()
                        .map(|(name, b)| (name.clone(), Json::num(*b as f64)))
                        .collect(),
                ),
            ),
            ("fault_plan".into(), Json::str(self.fault_plan.clone())),
            (
                "faults_injected".into(),
                Json::num(self.faults_injected as f64),
            ),
            ("retries".into(), Json::num(self.retries as f64)),
            (
                "recomputed_tasks".into(),
                Json::num(self.recomputed_tasks as f64),
            ),
            (
                "recovery_bytes".into(),
                Json::num(self.recovery_bytes as f64),
            ),
            (
                "residency_peak_bytes".into(),
                Json::num(self.residency_peak_bytes as f64),
            ),
            (
                "residency_max_task_bytes".into(),
                Json::num(self.residency_max_task_bytes as f64),
            ),
            (
                "mem_budget_bytes".into(),
                Json::num(self.mem_budget_bytes as f64),
            ),
            (
                "residency_fits_budget".into(),
                match self.residency_fits_budget {
                    None => Json::str("unlimited"),
                    Some(f) => Json::Bool(f),
                },
            ),
        ])
    }
}

impl std::fmt::Display for Explain {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::expr::{AggOp, UnaryOp};
    use crate::runtime::native::eval_graph;

    fn session() -> Session {
        Session::new(DriverConfig {
            workers: 2,
            p: 2,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn lazy_program_compiles_and_runs() {
        let s = session();
        let a = s.input("A", &[16, 8]);
        let b = s.input("B", &[8, 16]);
        let z = a.einsum("ij,jk->ik", &b).unwrap();
        let r = z.map(UnaryOp::Relu).unwrap().reduce("ik->i", AggOp::Sum).unwrap();
        let exe = s.compile_expr(&r).unwrap();
        assert_eq!(exe.provenance(), PlanProvenance::Planned);
        let mut inputs = HashMap::new();
        inputs.insert(a.id(), Tensor::random(&[16, 8], 1));
        inputs.insert(b.id(), Tensor::random(&[8, 16], 2));
        let (outs, rep) = exe.run(&inputs).unwrap();
        assert_eq!(rep.provenance, PlanProvenance::Planned);
        assert!(rep.plan_s > 0.0);
        let want = eval_graph(exe.graph(), &inputs).unwrap();
        assert_eq!(outs[&r.id()], want[&r.id()]);
    }

    #[test]
    fn compile_expr_resets_the_staging_program() {
        let s = session();
        let a = s.input("A", &[8, 8]);
        let b = s.input("B", &[8, 8]);
        let z = a.einsum("ij,jk->ik", &b).unwrap();
        s.compile_expr(&z).unwrap();
        // fresh program: the new input cannot combine with the old one
        let c = s.input("C", &[8, 8]);
        assert!(a.einsum("ij,jk->ik", &c).is_err());
        // but builds cleanly on its own, and hits the cache (same shape)
        let d = s.input("D", &[8, 8]);
        let w = c.einsum("pq,qr->pr", &d).unwrap();
        let exe = s.compile_expr(&w).unwrap();
        assert_eq!(exe.provenance(), PlanProvenance::CacheHit);
        assert_eq!(s.stats().hits, 1);
    }

    #[test]
    fn explain_exposes_program_and_passes() {
        let s = session();
        let a = s.input("A", &[16, 16]);
        let b = s.input("B", &[16, 16]);
        let z = a.einsum("ij,jk->ik", &b).unwrap();
        let exe = s.compile_expr(&z).unwrap();
        // default pipeline = the task-graph-neutral Safe set
        assert_eq!(
            exe.passes(),
            &["elide-identity-repart".to_string(), "dead-rel-elim".to_string()]
        );
        assert!(!exe.tra_program().is_empty());
        let ex = s.explain(&exe);
        let text = ex.render();
        assert!(text.contains("Join"), "{text}");
        assert!(text.contains("elide-identity-repart"), "{text}");
        assert!(text.contains("task graph:"), "{text}");
        assert!(ex.to_json().render().contains("\"program\""));
    }

    #[test]
    fn explain_reports_per_link_class_bytes() {
        use crate::sim::network::{NetworkProfile, Topology};
        let net = NetworkProfile::cpu_cluster();
        let s = Session::new(DriverConfig {
            workers: 4,
            p: 4,
            network: net.clone(),
            topology: Some(Topology::three_level_of(&net, 4)),
            ..Default::default()
        })
        .unwrap();
        let a = s.input("A", &[32, 32]);
        let b = s.input("B", &[32, 32]);
        let z = a.einsum("ij,jk->ik", &b).unwrap();
        let exe = s.compile_expr(&z).unwrap();
        let ex = s.explain(&exe);
        // one entry per link class, rolling up to the class ledger
        assert_eq!(ex.bytes_by_link.len(), 3, "{:?}", ex.bytes_by_link);
        let by_link: u64 = ex.bytes_by_link.iter().map(|(_, b)| *b).sum();
        let by_class = ex.bytes_input + ex.bytes_join + ex.bytes_agg + ex.bytes_repart;
        assert_eq!(by_link, by_class);
        assert!(ex.render().contains("modeled bytes by link:"), "{}", ex.render());
        assert!(ex.to_json().render().contains("\"bytes_by_link\""));
    }

    #[test]
    fn session_faults_and_run_with_options() {
        use crate::sim::faults::{FaultPlan, RunOptions};
        // clean baseline session
        let cs = session();
        let a = cs.input("A", &[16, 16]);
        let b = cs.input("B", &[16, 16]);
        let z = a.einsum("ij,jk->ik", &b).unwrap();
        let exe = cs.compile_expr(&z).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert(a.id(), Tensor::random(&[16, 16], 1));
        inputs.insert(b.id(), Tensor::random(&[16, 16], 2));
        let (clean, clean_rep) = exe.run(&inputs).unwrap();
        assert_eq!(clean_rep.exec.faults_injected, 0);
        assert!(cs.explain(&exe).render().contains("fault plan: none"));
        // same config plus an injected transient fault
        let s = Session::new(DriverConfig {
            workers: 2,
            p: 2,
            faults: Some(FaultPlan::new().transient(0, 1)),
            ..Default::default()
        })
        .unwrap();
        let a2 = s.input("A", &[16, 16]);
        let b2 = s.input("B", &[16, 16]);
        let z2 = a2.einsum("ij,jk->ik", &b2).unwrap();
        let exe2 = s.compile_expr(&z2).unwrap();
        let ex = s.explain(&exe2);
        assert_eq!(ex.fault_plan, "task:0:transient:1");
        assert_eq!(ex.faults_injected, 0); // the model never injects
        assert!(ex.to_json().render().contains("\"fault_plan\""));
        let mut inputs2 = HashMap::new();
        inputs2.insert(a2.id(), Tensor::random(&[16, 16], 1));
        inputs2.insert(b2.id(), Tensor::random(&[16, 16], 2));
        let (outs, rep) = exe2.run(&inputs2).unwrap();
        assert_eq!(outs[&z2.id()], clean[&z.id()]); // bitwise despite the fault
        assert_eq!(rep.exec.faults_injected, 1);
        assert!(rep.exec.retries >= 1);
        assert!(rep.to_json().render().contains("\"faults_injected\":1"));
        // per-call options override: an expired deadline is a typed error
        let err = exe2
            .run_with(
                &inputs2,
                &RunOptions {
                    deadline: Some(std::time::Duration::ZERO),
                    ..Default::default()
                },
            )
            .unwrap_err();
        assert!(err.is_deadline(), "{err}");
    }

    #[test]
    fn session_and_executable_are_send_sync() {
        // Compile-time assertion: the serving pool shares one Session
        // across worker threads and moves Executables between them. If
        // either type loses Send + Sync (e.g. a future field gains
        // non-atomic interior mutability), this stops compiling.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Session>();
        assert_send_sync::<Executable>();
        assert_send_sync::<CacheStats>();
    }

    #[test]
    fn cache_stats_coherent_under_concurrent_compile() {
        // N threads race compile() on one graph: exactly one artifact
        // must be published, every handle must share it, and the
        // counters — classified under the cache lock — must balance.
        let s = session();
        let a = s.input("A", &[16, 16]);
        let b = s.input("B", &[16, 16]);
        let z = a.einsum("ij,jk->ik", &b).unwrap();
        let g = z.graph();
        let n = 8u64;
        let keys: Vec<usize> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n)
                .map(|_| {
                    let (s, g) = (&s, &g);
                    scope.spawn(move || s.compile(g).unwrap().artifact_key())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(
            keys.windows(2).all(|w| w[0] == w[1]),
            "every executable must share the single published artifact"
        );
        let st = s.stats();
        assert_eq!(st.compiles, n);
        assert_eq!(st.hits + st.misses, st.compiles, "no dropped updates");
        assert_eq!(st.entries, 1);
        assert!(st.misses >= 1);
        assert_eq!(
            st.misses,
            1 + st.raced,
            "one publisher; every other miss must be counted as raced"
        );
        assert_eq!(st.planner_runs, st.misses, "one planner run per miss");
    }

    #[test]
    fn compile_with_plan_lowers_without_planning() {
        let s = session();
        let a = s.input("A", &[16, 16]);
        let b = s.input("B", &[16, 16]);
        let z = a.einsum("ij,jk->ik", &b).unwrap();
        let g = z.graph();
        let (plan, _) = s.plan(&g).unwrap();
        let planner_before = s.stats().planner_runs;
        let exe = s.compile_with_plan(&g, plan).unwrap();
        assert_eq!(exe.provenance(), PlanProvenance::Reused);
        assert_eq!(s.stats().planner_runs, planner_before, "no planning");
        assert_eq!(s.stats().entries, 0, "uncached");
        let mut inputs = HashMap::new();
        inputs.insert(a.id(), Tensor::random(&[16, 16], 1));
        inputs.insert(b.id(), Tensor::random(&[16, 16], 2));
        let (outs, rep) = exe.run(&inputs).unwrap();
        assert_eq!(rep.provenance, PlanProvenance::Reused);
        assert_eq!(rep.plan_s, 0.0);
        assert_eq!(rep.batched_with, 1);
        assert_eq!(rep.queue_wait_s, 0.0);
        let want = eval_graph(&g, &inputs).unwrap();
        assert_eq!(outs[&z.id()], want[&z.id()]);
    }

    #[test]
    fn compile_fresh_bypasses_the_cache() {
        let s = session();
        let a = s.input("A", &[8, 8]);
        let b = s.input("B", &[8, 8]);
        let z = a.einsum("ij,jk->ik", &b).unwrap();
        let g = z.graph();
        for _ in 0..2 {
            let exe = s.compile_fresh(&g).unwrap();
            assert_eq!(exe.provenance(), PlanProvenance::Planned);
        }
        let st = s.stats();
        assert_eq!(st.planner_runs, 2);
        assert_eq!(st.entries, 0);
    }
}

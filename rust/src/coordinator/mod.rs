//! The L3 coordinator: configuration, the high-level [`driver::Driver`]
//! (plan → lower → place → execute → report), and the CLI front-end used
//! by the `eindecomp` binary.

pub mod cli;
pub mod driver;

//! The L3 coordinator: configuration, the compile-once / run-many
//! [`session::Session`] API (plan → lower → place once, execute many
//! times through a canonical-signature plan cache), the legacy
//! [`driver::Driver`] shim, and the CLI front-end used by the
//! `eindecomp` binary.

pub mod cli;
pub mod driver;
pub mod session;

//! Differential tests for out-of-core execution (`runtime/spill.rs`).
//!
//! The memory-budgeted [`TileStore`] must be *invisible* in every output
//! bit: spilling cold tiles to disk and faulting them back on demand may
//! change timing and counters, never values. These tests lock in:
//!
//! 1. **Differential**: for every bench workload × worker count × exec
//!    mode × budget arm (tight, roomy, unlimited), outputs are bitwise
//!    identical to the unbudgeted run;
//! 2. **Property**: `peak_resident_bytes[w]` never exceeds the budget on
//!    any worker — the reserve-before-publish protocol makes this true by
//!    construction, and the report must prove it;
//! 3. **Zero overhead**: an unbudgeted run engages none of the spill
//!    machinery — all spill counters are zero and the report summary has
//!    no spill segment — while peak residency is still tracked.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::einsum::graph::{EinGraph, VertexId};
use eindecomp::models::{ffnn, llama, matchain};
use eindecomp::runtime::NativeEngine;
use eindecomp::sim::{Cluster, ExecMode, MemoryBudget, NetworkProfile};
use eindecomp::tensor::Tensor;
use std::collections::HashMap;

/// One bench workload: a graph plus deterministic inputs.
fn workloads() -> Vec<(&'static str, EinGraph, HashMap<VertexId, Tensor>)> {
    let mut out = Vec::new();
    let chain = matchain::chain_graph(24, false).unwrap();
    let inputs = matchain::chain_inputs(&chain, 7);
    out.push(("chain", chain.graph, inputs));
    let skewed = matchain::chain_graph(20, true).unwrap();
    let inputs = matchain::chain_inputs(&skewed, 11);
    out.push(("chain-skewed", skewed.graph, inputs));
    let step = ffnn::ffnn_step(16, 32, 24, 8).unwrap();
    let state = ffnn::FfnnState::init(32, 24, 8, 13);
    let x = Tensor::random(&[16, 32], 17);
    let t = Tensor::random(&[16, 8], 19);
    let inputs = ffnn::step_inputs(&step, &state, x, t);
    out.push(("ffnn", step.graph, inputs));
    let cfg = llama::LlamaConfig::llama7b(1, 64).scaled(64, 32);
    let model = llama::llama_graph(&cfg).unwrap();
    let inputs = llama::llama_inputs(&model, 23);
    out.push(("tiny-llama", model.graph, inputs));
    out
}

/// Largest single-task working set of the lowered graph: a budget below
/// this cannot run at all, anything at or above it must complete (spilling
/// as needed). Mirrors the reserve path's accounting: a task needs its
/// output tile plus every dep tile resident at once.
fn working_set_floor(cluster: &Cluster, g: &EinGraph, plan: &eindecomp::decomp::Plan) -> u64 {
    let tg = cluster.lower(g, plan).unwrap();
    tg.tasks
        .iter()
        .map(|t| {
            t.out_bytes as u64
                + t.deps
                    .iter()
                    .map(|d| tg.tasks[d.0].out_bytes as u64)
                    .sum::<u64>()
        })
        .max()
        .unwrap_or(0)
}

fn assert_bitwise_eq(
    a: &HashMap<VertexId, Tensor>,
    b: &HashMap<VertexId, Tensor>,
    outs: &[VertexId],
    ctx: &str,
) {
    for &o in outs {
        let (x, y) = (&a[&o], &b[&o]);
        assert_eq!(x.shape(), y.shape(), "{ctx}: output {o} shape");
        for (i, (u, v)) in x.data().iter().zip(y.data()).enumerate() {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "{ctx}: output {o} diverges at element {i} ({u} vs {v})"
            );
        }
    }
}

/// The tentpole acceptance test: budgeted ≡ unbudgeted, bitwise, across
/// every workload × p × exec mode × budget arm, with per-worker peak
/// residency provably under the budget.
#[test]
fn budgeted_runs_are_bitwise_identical_across_budgets() {
    let engine = NativeEngine::new();
    let roles = LabelRoles::by_convention();
    let mut tight_spill_total = 0u64;
    for (name, g, inputs) in workloads() {
        let outs = g.outputs();
        for p in [2usize, 4, 8] {
            let plan = assign(&g, &Strategy::EinDecomp, p, &roles).unwrap();
            for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
                let base = Cluster::new(p, NetworkProfile::loopback()).with_exec_mode(mode);
                let (want, base_rep) = base.execute(&g, &plan, &engine, &inputs).unwrap();
                let peak = base_rep.peak_resident_bytes.iter().copied().max().unwrap();
                let floor = working_set_floor(&base, &g, &plan);
                assert!(floor > 0 && peak >= floor, "{name} p={p}: floor {floor} peak {peak}");
                // tight forces eviction (well under peak) but always
                // admits a single working set; roomy rarely spills.
                let tight = (peak / 3).max(2 * floor);
                let roomy = peak.max(2 * floor);
                for budget in [tight, roomy] {
                    let cluster = base
                        .clone()
                        .with_mem_budget(MemoryBudget::per_worker_bytes(budget));
                    let (got, rep) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
                    let ctx = format!("{name} p={p} {mode:?} budget={budget}");
                    assert_bitwise_eq(&got, &want, &outs, &ctx);
                    assert_eq!(rep.peak_resident_bytes.len(), p, "{ctx}");
                    for (w, &resident) in rep.peak_resident_bytes.iter().enumerate() {
                        assert!(
                            resident <= budget,
                            "{ctx}: worker {w} peak {resident} exceeds budget"
                        );
                    }
                    if budget == tight {
                        tight_spill_total += rep.spill_bytes;
                    }
                    // a fault implies bytes went to cold storage first
                    // (intermediates) or a view was re-sliced (inputs);
                    // either way the counters must be consistent.
                    if rep.spill_bytes > 0 {
                        assert!(rep.spill_faults > 0 || rep.spill_stall_s >= 0.0, "{ctx}");
                    }
                }
            }
        }
    }
    assert!(
        tight_spill_total > 0,
        "tight budget arms never spilled — the out-of-core path was not exercised"
    );
}

/// Unbudgeted runs must not pay for the spill machinery: every spill
/// counter is zero, the summary has no spill segment, and the modeled
/// ledger matches a second unbudgeted run exactly — while per-worker peak
/// residency is still tracked (it feeds `explain` and the offload bench).
#[test]
fn unbudgeted_runs_have_zero_spill_overhead() {
    let engine = NativeEngine::new();
    let roles = LabelRoles::by_convention();
    let chain = matchain::chain_graph(24, false).unwrap();
    let inputs = matchain::chain_inputs(&chain, 3);
    let plan = assign(&chain.graph, &Strategy::EinDecomp, 4, &roles).unwrap();
    for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
        let cluster = Cluster::new(4, NetworkProfile::loopback()).with_exec_mode(mode);
        let (_, rep) = cluster.execute(&chain.graph, &plan, &engine, &inputs).unwrap();
        assert_eq!(rep.spill_bytes, 0, "{mode:?}");
        assert_eq!(rep.spill_faults, 0, "{mode:?}");
        assert_eq!(rep.spill_stall_s, 0.0, "{mode:?}");
        assert!(!rep.summary().contains("spilled="), "{mode:?}: {}", rep.summary());
        assert_eq!(rep.peak_resident_bytes.len(), 4, "{mode:?}");
        assert!(
            rep.peak_resident_bytes.iter().any(|&b| b > 0),
            "{mode:?}: peak residency must be tracked even without a budget"
        );
        // the modeled ledger is budget-independent AND run-independent
        let (_, again) = cluster.execute(&chain.graph, &plan, &engine, &inputs).unwrap();
        assert_eq!(rep.bytes_moved, again.bytes_moved);
        assert_eq!(rep.kernel_calls, again.kernel_calls);
        assert_eq!(rep.peak_resident_bytes, again.peak_resident_bytes);
    }
}

/// The budget is threaded through the driver/session stack too: a
/// [`Session`] compiled with `mem_budget` produces bitwise-identical
/// outputs and reports its spill counters through `RunReport::to_json`.
#[test]
fn session_mem_budget_round_trips_through_reports() {
    use eindecomp::coordinator::driver::DriverConfig;
    use eindecomp::coordinator::session::Session;
    let chain = matchain::chain_graph(24, false).unwrap();
    let inputs = matchain::chain_inputs(&chain, 5);
    let outs = chain.graph.outputs();
    let run = |budget: Option<MemoryBudget>| {
        let cfg = DriverConfig {
            workers: 2,
            p: 2,
            mem_budget: budget,
            ..Default::default()
        };
        let session = Session::new(cfg).unwrap();
        let exe = session.compile(&chain.graph).unwrap();
        exe.run(&inputs).unwrap()
    };
    let (want, base) = run(None);
    let floor = base.exec.peak_resident_bytes.iter().copied().max().unwrap();
    let (got, rep) = run(Some(MemoryBudget::per_worker_bytes(floor.max(1))));
    assert_bitwise_eq(&got, &want, &outs, "session budget=peak");
    let json = rep.to_json().render();
    for key in ["peak_resident_bytes", "spill_bytes", "spill_faults", "spill_stall_s"] {
        assert!(json.contains(key), "RunReport json missing {key}: {json}");
    }
}

//! Property-based tests (hand-rolled generators — no proptest crate in
//! this container; see Cargo.toml note). Each property runs against many
//! seeded random cases; failures report the seed.
//!
//! Core invariants:
//!  * TRA rewrite equivalence (paper §4.3): for ANY EinSum and ANY valid
//!    partitioning vector, the join->aggregate rewrite equals dense
//!    evaluation;
//!  * partition/assemble round-trips for any balanced tiling;
//!  * lowered task graphs execute to the same result as dense evaluation
//!    for arbitrary per-vertex plans (routing/repartition invariant);
//!  * viable() enumerations respect the exactly-p constraint and bounds;
//!  * cost-model sanity (non-negativity, zero at identity).

use eindecomp::decomp::viable::viable;
use eindecomp::decomp::{plan_graph, Plan, PlanMode, PlannerConfig};
use eindecomp::einsum::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use eindecomp::einsum::graph::EinGraph;
use eindecomp::einsum::label::Label;
use eindecomp::runtime::native::eval_einsum;
use eindecomp::runtime::NativeEngine;
use eindecomp::sim::{Cluster, NetworkProfile};
use eindecomp::tensor::Tensor;
use eindecomp::tra::ops::eval_einsum_tra;
use eindecomp::util::Rng;
use std::collections::HashMap;

fn labset() -> Vec<Label> {
    ["i", "j", "k", "m", "n"].iter().map(|s| Label::new(s)).collect()
}

/// Random binary EinSum over 1-3 labels per operand with random ops.
fn random_binary(rng: &mut Rng) -> (EinSum, Vec<usize>, Vec<usize>) {
    let labs = labset();
    let nx = 1 + rng.next_below(3);
    let ny = 1 + rng.next_below(3);
    let mut pool = labs.clone();
    let mut lx = Vec::new();
    for _ in 0..nx {
        if pool.is_empty() { break; }
        let i = rng.next_below(pool.len());
        lx.push(pool.remove(i));
    }
    let mut ly = Vec::new();
    for _ in 0..ny {
        if !lx.is_empty() && rng.next_f32() < 0.5 {
            let cand = lx[rng.next_below(lx.len())];
            if !ly.contains(&cand) {
                ly.push(cand);
                continue;
            }
        }
        if let Some(l) = pool.pop() {
            ly.push(l);
        }
    }
    if ly.is_empty() {
        ly.push(lx[0]);
    }
    let uniq: Vec<Label> = {
        let mut u = lx.clone();
        for &l in &ly {
            if !u.contains(&l) {
                u.push(l);
            }
        }
        u
    };
    let mut lz = Vec::new();
    for &l in &uniq {
        if rng.next_f32() < 0.6 {
            lz.push(l);
        }
    }
    if lz.is_empty() && rng.next_f32() < 0.8 {
        lz.push(uniq[rng.next_below(uniq.len())]);
    }
    let join = [JoinOp::Mul, JoinOp::Add, JoinOp::SquaredDiff, JoinOp::AbsDiff, JoinOp::Max]
        [rng.next_below(5)];
    let agg = [AggOp::Sum, AggOp::Max, AggOp::Min][rng.next_below(3)];
    let sizes = [2usize, 3, 4, 5, 6, 8];
    let mut bound_of: HashMap<Label, usize> = HashMap::new();
    for &l in &uniq {
        bound_of.insert(l, sizes[rng.next_below(sizes.len())]);
    }
    let bx: Vec<usize> = lx.iter().map(|l| bound_of[l]).collect();
    let by: Vec<usize> = ly.iter().map(|l| bound_of[l]).collect();
    (EinSum::Binary { lx, ly, lz, join, agg }, bx, by)
}

fn random_part(rng: &mut Rng, bounds: &[usize]) -> Vec<usize> {
    bounds.iter().map(|&b| 1 + rng.next_below(b.min(4))).collect()
}

#[test]
fn prop_tra_rewrite_equals_dense() {
    let engine = NativeEngine::new();
    let mut checked = 0;
    for seed in 0..200u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let (op, bx, by) = random_binary(&mut rng);
        let x = Tensor::random(&bx, seed * 2 + 1);
        let y = Tensor::random(&by, seed * 2 + 2);
        let dense = match eval_einsum(&op, &[&x, &y]) {
            Ok(d) => d,
            Err(_) => continue,
        };
        let ubounds = eindecomp::decomp::viable::unique_label_bounds(&op, &[&bx, &by]);
        let d = random_part(&mut rng, &ubounds);
        let rel = eval_einsum_tra(&op, &[&x, &y], &d, &engine)
            .unwrap_or_else(|e| panic!("seed {seed}: {e} (op {op}, d {d:?})"));
        let assembled = rel.assemble().unwrap();
        assert!(
            assembled.allclose(&dense, 1e-3, 1e-4),
            "seed {seed}: TRA != dense for {op}, d={d:?}, diff={}",
            assembled.max_abs_diff(&dense).unwrap()
        );
        checked += 1;
    }
    assert!(checked > 150, "only {checked} cases checked");
}

#[test]
fn prop_unary_tra_rewrite_equals_dense() {
    let engine = NativeEngine::new();
    for seed in 0..100u64 {
        let mut rng = Rng::seed_from_u64(1000 + seed);
        let labs = labset();
        let rank = 1 + rng.next_below(3);
        let lx: Vec<Label> = labs[..rank].to_vec();
        let keep = rng.next_below(rank + 1);
        let mut lz = lx.clone();
        while lz.len() > keep {
            let i = rng.next_below(lz.len());
            lz.remove(i);
        }
        let u = [UnaryOp::Identity, UnaryOp::Exp, UnaryOp::Relu, UnaryOp::Square]
            [rng.next_below(4)];
        let agg = [AggOp::Sum, AggOp::Max][rng.next_below(2)];
        let op = EinSum::Unary { lx: lx.clone(), lz, op: u, agg };
        let bx: Vec<usize> = (0..rank).map(|_| 2 + rng.next_below(6)).collect();
        let x = Tensor::random(&bx, seed + 5);
        let dense = eval_einsum(&op, &[&x]).unwrap();
        let d = random_part(&mut rng, &bx);
        let rel = eval_einsum_tra(&op, &[&x], &d, &engine).unwrap();
        assert!(
            rel.assemble().unwrap().allclose(&dense, 1e-3, 1e-4),
            "seed {seed}: unary TRA mismatch"
        );
    }
}

#[test]
fn prop_partition_assemble_roundtrip() {
    for seed in 0..100u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let rank = 1 + rng.next_below(4);
        let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.next_below(9)).collect();
        let t = Tensor::random(&shape, seed);
        let part: Vec<usize> = shape.iter().map(|&b| 1 + rng.next_below(b)).collect();
        let rel = eindecomp::tra::relation::TensorRelation::partition(&t, &part).unwrap();
        assert_eq!(rel.assemble().unwrap(), t, "seed {seed} part {part:?}");
        assert_eq!(rel.bytes(), t.bytes());
    }
}

#[test]
fn prop_viable_products_and_bounds() {
    for seed in 0..50u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let op = EinSum::contraction(
            vec![Label::new("i"), Label::new("j")],
            vec![Label::new("j"), Label::new("k")],
            vec![Label::new("i"), Label::new("k")],
        );
        let bounds: Vec<usize> = (0..3).map(|_| 4 << rng.next_below(4)).collect();
        let p = 1usize << rng.next_below(5);
        if let Ok(ds) = viable(&op, &bounds, p) {
            for d in &ds {
                assert_eq!(d.iter().product::<usize>(), p, "seed {seed}");
                for (x, b) in d.iter().zip(&bounds) {
                    assert!(x <= b && x.is_power_of_two());
                }
            }
            let mut sorted = ds.clone();
            sorted.sort();
            sorted.dedup();
            assert_eq!(sorted.len(), ds.len());
        }
    }
}

#[test]
fn prop_cost_model_sane() {
    use eindecomp::decomp::cost::{cost_agg, cost_join, cost_repart};
    for seed in 0..100u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let (op, bx, by) = random_binary(&mut rng);
        let ubounds = eindecomp::decomp::viable::unique_label_bounds(&op, &[&bx, &by]);
        let d = random_part(&mut rng, &ubounds);
        let j = cost_join(&op, &[&bx, &by], &d).unwrap();
        let a = cost_agg(&op, &[&bx, &by], &d).unwrap();
        assert!(j >= 0.0 && a >= 0.0, "seed {seed}");
        let bound: Vec<usize> = (0..2).map(|_| 2 + rng.next_below(10)).collect();
        let d1: Vec<usize> = bound.iter().map(|&b| 1 + rng.next_below(b)).collect();
        let d2: Vec<usize> = bound.iter().map(|&b| 1 + rng.next_below(b)).collect();
        assert_eq!(cost_repart(&d1, &d1, &bound), 0.0);
        assert!(cost_repart(&d1, &d2, &bound) >= 0.0);
        assert!(cost_repart(&d2, &d1, &bound) >= 0.0);
    }
}

#[test]
fn prop_random_plans_execute_correctly() {
    let engine = NativeEngine::new();
    for seed in 0..40u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let s = 6 + rng.next_below(8);
        let mut g = EinGraph::new();
        let a = g.input("A", vec![s, s]);
        let b = g.input("B", vec![s, s]);
        let c = g.input("C", vec![s, s]);
        let z1 = g
            .add(
                "Z1",
                EinSum::contraction(
                    vec![Label::new("i"), Label::new("j")],
                    vec![Label::new("j"), Label::new("k")],
                    vec![Label::new("i"), Label::new("k")],
                ),
                vec![a, b],
            )
            .unwrap();
        let z2 = g
            .add(
                "Z2",
                EinSum::contraction(
                    vec![Label::new("i"), Label::new("k")],
                    vec![Label::new("k"), Label::new("m")],
                    vec![Label::new("i"), Label::new("m")],
                ),
                vec![z1, c],
            )
            .unwrap();
        let mut plan = Plan::default();
        plan.parts
            .insert(z1, (0..3).map(|_| 1 + rng.next_below(s.min(4))).collect());
        plan.parts
            .insert(z2, (0..3).map(|_| 1 + rng.next_below(s.min(4))).collect());
        plan.finalize_inputs(&g);
        let ta = Tensor::random(&[s, s], seed + 10);
        let tb = Tensor::random(&[s, s], seed + 11);
        let tc = Tensor::random(&[s, s], seed + 12);
        let mut inputs = HashMap::new();
        inputs.insert(a, ta.clone());
        inputs.insert(b, tb.clone());
        inputs.insert(c, tc.clone());
        let workers = 1 + rng.next_below(6);
        let cluster = Cluster::new(workers, NetworkProfile::loopback());
        let (outs, _) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
        let w1 = eval_einsum(&g.vertex(z1).op, &[&ta, &tb]).unwrap();
        let want = eval_einsum(&g.vertex(z2).op, &[&w1, &tc]).unwrap();
        assert!(
            outs[&z2].allclose(&want, 1e-3, 1e-4),
            "seed {seed}: wrong result under random plan"
        );
    }
}

#[test]
fn prop_planner_never_worse_than_greedy_on_trees() {
    for seed in 0..20u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let dims: Vec<usize> = (0..5).map(|_| 8 << rng.next_below(4)).collect();
        let mut g = EinGraph::new();
        let mut cur = g.input("X0", vec![dims[0], dims[1]]);
        for l in 0..3 {
            let w = g.input(&format!("W{l}"), vec![dims[l + 1], dims[l + 2]]);
            let li = Label::new("i");
            let lj = Label::new(&format!("t{l}"));
            let lk = Label::new(&format!("t{}", l + 1));
            cur = g
                .add(
                    &format!("H{l}"),
                    EinSum::contraction(vec![li, lj], vec![lj, lk], vec![li, lk]),
                    vec![cur, w],
                )
                .unwrap();
        }
        let exact = plan_graph(
            &g,
            &PlannerConfig {
                p: 8,
                mode: PlanMode::ExactTree,
                off_path_cost: false,
                ..Default::default()
            },
        );
        let greedy = plan_graph(
            &g,
            &PlannerConfig {
                p: 8,
                mode: PlanMode::Greedy,
                off_path_cost: false,
                ..Default::default()
            },
        );
        if let (Ok(e), Ok(gr)) = (exact, greedy) {
            assert!(
                e.predicted_cost <= gr.predicted_cost + 1e-6,
                "seed {seed}: exact {:.0} > greedy {:.0}",
                e.predicted_cost,
                gr.predicted_cost
            );
        }
    }
}

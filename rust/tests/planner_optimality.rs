//! Planner optimality, proven by exhaustion on tiny graphs.
//!
//! For graphs of ≤ 4 compute vertices and p ∈ {2, 4}, enumerate EVERY
//! combination of viable partitioning vectors, score each complete plan
//! with `Plan::total_cost` (the objective `plan_graph` reports as
//! `predicted_cost`), and assert:
//!
//! * `PlanMode::ExactTree` matches the brute-force optimum exactly
//!   (paper §8.2's optimality claim, machine-checked);
//! * `PlanMode::Linearized` and `PlanMode::Greedy` are never *better*
//!   than the exact DP (they approximate the same objective).

use eindecomp::decomp::viable::{pow2_at_least, unique_label_bounds, viable};
use eindecomp::decomp::{plan_graph, Plan, PlanMode, PlannerConfig};
use eindecomp::einsum::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use eindecomp::einsum::graph::{EinGraph, VertexId};
use eindecomp::einsum::label::labels;

/// All viable d-vectors for every compute vertex of `g` at kernel-call
/// target `p` (after the same pow2 rounding the planner applies).
fn candidates(g: &EinGraph, p: usize) -> Vec<(VertexId, Vec<Vec<usize>>)> {
    let p = pow2_at_least(p);
    g.vertices()
        .iter()
        .filter(|v| !matches!(v.op, EinSum::Input))
        .map(|v| {
            let in_bounds: Vec<&[usize]> = v
                .inputs
                .iter()
                .map(|&i| g.vertex(i).bound.as_slice())
                .collect();
            let ub = unique_label_bounds(&v.op, &in_bounds);
            (v.id, viable(&v.op, &ub, p).unwrap())
        })
        .collect()
}

/// Brute-force the cheapest complete plan by Cartesian product over all
/// per-vertex candidates. Returns (best cost, number of plans scored).
fn brute_force(g: &EinGraph, p: usize) -> (f64, usize) {
    let cands = candidates(g, p);
    let mut idx = vec![0usize; cands.len()];
    let mut best = f64::INFINITY;
    let mut scored = 0usize;
    loop {
        let mut plan = Plan::default();
        for (slot, (v, ds)) in idx.iter().zip(&cands) {
            plan.parts.insert(*v, ds[*slot].clone());
        }
        plan.finalize_inputs(g);
        let cost = plan.total_cost(g).unwrap();
        scored += 1;
        if cost < best {
            best = cost;
        }
        // odometer over the candidate lists
        let mut d = cands.len();
        loop {
            if d == 0 {
                return (best, scored);
            }
            d -= 1;
            idx[d] += 1;
            if idx[d] < cands[d].1.len() {
                break;
            }
            idx[d] = 0;
        }
    }
}

fn check_graph(name: &str, g: &EinGraph) {
    assert!(g.is_tree_like(), "{name}: exact DP needs a tree-like graph");
    let compute = g.len() - g.inputs().len();
    assert!(compute <= 4, "{name}: keep brute force tiny");
    for p in [2usize, 4] {
        let (best, scored) = brute_force(g, p);
        let cfg = |mode| PlannerConfig {
            p,
            mode,
            off_path_cost: false,
            ..Default::default()
        };
        let exact = plan_graph(g, &cfg(PlanMode::ExactTree)).unwrap();
        assert!(
            (exact.predicted_cost - best).abs() <= 1e-9 * best.max(1.0),
            "{name} p={p}: exact DP {} != brute-force optimum {best} \
             (over {scored} complete plans)",
            exact.predicted_cost
        );
        for mode in [PlanMode::Linearized, PlanMode::Greedy] {
            let approx = plan_graph(g, &cfg(mode)).unwrap();
            assert!(
                approx.predicted_cost >= exact.predicted_cost - 1e-9 * best.max(1.0),
                "{name} p={p}: {mode:?} cost {} beats exact {} — objective mismatch",
                approx.predicted_cost,
                exact.predicted_cost
            );
        }
    }
}

#[test]
fn single_matmul_exact_is_optimal() {
    let mut g = EinGraph::new();
    let a = g.input("A", vec![16, 16]);
    let b = g.input("B", vec![16, 16]);
    g.add(
        "Z",
        EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
        vec![a, b],
    )
    .unwrap();
    check_graph("matmul", &g);
}

#[test]
fn skewed_matmul_exact_is_optimal() {
    // skew makes the optimum non-square — a real test of the DP's search
    let mut g = EinGraph::new();
    let a = g.input("A", vec![32, 4]);
    let b = g.input("B", vec![4, 32]);
    g.add(
        "Z",
        EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
        vec![a, b],
    )
    .unwrap();
    check_graph("skewed-matmul", &g);
}

#[test]
fn two_op_chain_exact_is_optimal() {
    let mut g = EinGraph::new();
    let a = g.input("A", vec![16, 8]);
    let b = g.input("B", vec![8, 16]);
    let c = g.input("C", vec![16, 16]);
    let ab = g
        .add(
            "AB",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    g.add(
        "ABC",
        EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
        vec![ab, c],
    )
    .unwrap();
    check_graph("two-op-chain", &g);
}

#[test]
fn chain_with_map_and_reduce_exact_is_optimal() {
    // 4 compute vertices: contraction -> elementwise -> map -> reduce;
    // the cross-vertex repartition terms are where greedy goes wrong.
    let mut g = EinGraph::new();
    let a = g.input("A", vec![16, 16]);
    let b = g.input("B", vec![16, 16]);
    let c = g.input("C", vec![16, 16]);
    let ab = g
        .add(
            "AB",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    let e = g
        .add(
            "E",
            EinSum::elementwise(labels("i k"), labels("i k"), JoinOp::Add),
            vec![ab, c],
        )
        .unwrap();
    let r = g
        .add("R", EinSum::map(labels("i k"), UnaryOp::Relu), vec![e])
        .unwrap();
    g.add(
        "S",
        EinSum::reduce(labels("i k"), labels("i"), AggOp::Sum),
        vec![r],
    )
    .unwrap();
    check_graph("map-reduce-chain", &g);
}

//! Differential suite for the zero-copy TRA data plane.
//!
//! The refactor's contract is that moving tiles as strided views —
//! instead of memcpy'ing them at every partition/join/repartition seam —
//! changes **no bytes anywhere**: every test here runs the same pipeline
//! twice, once through the retained copy-based baseline
//! (`TensorRelation::partition_owned` + kernels on materialized tiles,
//! exactly the pre-refactor data plane) and once through the view path,
//! and asserts `==` on the assembled `Tensor`s (f32 bitwise, via
//! `PartialEq`). The suite also pins the tile-to-tile repartition's byte
//! accounting against the planner's `cost_repart` charge, and shows the
//! buffer pool reaches a steady state with no allocation growth across
//! repeated evaluations.

use eindecomp::decomp::cost::cost_repart;
use eindecomp::einsum::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use eindecomp::einsum::label::{concat_dedup, labels, project};
use eindecomp::runtime::{KernelEngine, NativeEngine};
use eindecomp::tensor::{Tensor, TensorView};
use eindecomp::tra::ops::{aggregate, eval_einsum_tra, join, repartition_with_stats};
use eindecomp::tra::relation::TensorRelation;
use eindecomp::util::{with_intra_op_pool, BufferPool};

/// Run one EinSum through the TRA pipeline (partition -> per-tile kernel
/// -> aggregate -> assemble). `owned = true` replays the pre-refactor
/// copy-based data plane: owned contiguous tiles, kernels on
/// materialized tensors. `threads` drives the intra-op shard pool
/// (1 = serial). Both modes must agree bitwise at every thread count.
fn run_tra(op: &EinSum, inputs: &[&Tensor], d: &[usize], owned: bool, threads: usize) -> Tensor {
    let uniq = op.unique_labels();
    let lz = op.lz().unwrap().clone();
    let in_bounds: Vec<&[usize]> = inputs.iter().map(|t| t.shape()).collect();
    let bz = op.infer_bound(&in_bounds).unwrap();
    let dz = project(d, &lz, &uniq);
    let engine = NativeEngine::new();
    with_intra_op_pool(threads, |scope| match op {
        EinSum::Unary { lx, agg, .. } => {
            let dx = project(d, lx, &uniq);
            let rx = if owned {
                TensorRelation::partition_owned(inputs[0], &dx)
            } else {
                TensorRelation::partition(inputs[0], &dx)
            }
            .unwrap();
            let mut tuples = Vec::new();
            for (key, tile) in rx.iter() {
                let t = if owned {
                    let o = tile.to_tensor();
                    engine.eval_scoped(op, &[&o], scope).unwrap()
                } else {
                    engine.eval_view_scoped(op, &[tile], scope).unwrap()
                };
                tuples.push((key, t));
            }
            let grouped = aggregate(tuples, lx, &lz, *agg).unwrap();
            let tiles: Vec<Tensor> = grouped.into_iter().map(|(_, t)| t).collect();
            TensorRelation::from_tiles(bz.clone(), dz.clone(), tiles)
                .unwrap()
                .assemble()
                .unwrap()
        }
        EinSum::Binary { lx, ly, agg, .. } => {
            let dx = project(d, lx, &uniq);
            let dy = project(d, ly, &uniq);
            let (rx, ry) = if owned {
                (
                    TensorRelation::partition_owned(inputs[0], &dx).unwrap(),
                    TensorRelation::partition_owned(inputs[1], &dy).unwrap(),
                )
            } else {
                (
                    TensorRelation::partition(inputs[0], &dx).unwrap(),
                    TensorRelation::partition(inputs[1], &dy).unwrap(),
                )
            };
            let mut kernel = |a: &TensorView, b: &TensorView| {
                if owned {
                    let (ao, bo) = (a.to_tensor(), b.to_tensor());
                    engine.eval_scoped(op, &[&ao, &bo], scope)
                } else {
                    engine.eval_view_scoped(op, &[a, b], scope)
                }
            };
            let joined = join(&rx, &ry, lx, ly, &mut kernel).unwrap();
            let lj = concat_dedup(lx, ly);
            let grouped = aggregate(joined, &lj, &lz, *agg).unwrap();
            let tiles: Vec<Tensor> = grouped.into_iter().map(|(_, t)| t).collect();
            TensorRelation::from_tiles(bz.clone(), dz.clone(), tiles)
                .unwrap()
                .assemble()
                .unwrap()
        }
        EinSum::Input => unreachable!(),
    })
}

#[test]
fn figure1_partitionings_bitwise_equal() {
    let x = Tensor::random(&[8, 8], 1);
    let y = Tensor::random(&[8, 8], 2);
    let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
    for d in [[4usize, 1, 4], [2, 1, 8], [2, 4, 2], [2, 2, 4]] {
        let base = run_tra(&op, &[&x, &y], &d, true, 1);
        let view = run_tra(&op, &[&x, &y], &d, false, 1);
        assert_eq!(view, base, "d={d:?}");
        // the public entry point rides the same view path
        let rel = eval_einsum_tra(&op, &[&x, &y], &d, &NativeEngine::new()).unwrap();
        assert_eq!(rel.assemble().unwrap(), base, "d={d:?}");
    }
}

#[test]
fn uneven_bounds_bitwise_equal() {
    let x = Tensor::random(&[7, 10], 3);
    let y = Tensor::random(&[10, 5], 4);
    let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
    for d in [[1usize, 1, 1], [3, 2, 2], [7, 10, 5], [2, 3, 1]] {
        let base = run_tra(&op, &[&x, &y], &d, true, 1);
        let view = run_tra(&op, &[&x, &y], &d, false, 1);
        assert_eq!(view, base, "d={d:?}");
    }
}

#[test]
fn extended_ops_bitwise_equal() {
    // non-GEMM joins exercise the generic strided nest
    let x = Tensor::random(&[6, 8], 5);
    let y = Tensor::random(&[8, 4], 6);
    for (join_op, agg) in [(JoinOp::SquaredDiff, AggOp::Sum), (JoinOp::AbsDiff, AggOp::Max)] {
        let op = EinSum::Binary {
            lx: labels("i j"),
            ly: labels("j k"),
            lz: labels("i k"),
            join: join_op,
            agg,
        };
        for d in [[1usize, 1, 1], [2, 4, 2], [3, 2, 4]] {
            let base = run_tra(&op, &[&x, &y], &d, true, 1);
            let view = run_tra(&op, &[&x, &y], &d, false, 1);
            assert_eq!(view, base, "{join_op:?} d={d:?}");
        }
    }
}

#[test]
fn unary_reductions_bitwise_equal() {
    let x = Tensor::random(&[9, 12], 9);
    let reduce = EinSum::reduce(labels("i j"), labels("i"), AggOp::Max);
    let colsum = EinSum::reduce(labels("i j"), labels("j"), AggOp::Sum);
    let tmap = EinSum::Unary {
        lx: labels("i j"),
        lz: labels("j i"),
        op: UnaryOp::Exp,
        agg: AggOp::Sum,
    };
    for op in [&reduce, &colsum, &tmap] {
        for d in [[1usize, 1], [3, 4], [9, 12], [2, 5]] {
            let base = run_tra(op, &[&x], &d, true, 1);
            let view = run_tra(op, &[&x], &d, false, 1);
            assert_eq!(view, base, "{op:?} d={d:?}");
        }
    }
}

#[test]
fn intra_op_threads_bitwise_equal() {
    // 64x64 at d=[2,2,4]: per-tile GEMMs are 32x32x16 = 16384 >= the
    // shard gate, so 2/8-thread runs actually fork shards.
    let x = Tensor::random(&[64, 64], 10);
    let y = Tensor::random(&[64, 64], 11);
    let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
    let d = [2usize, 2, 4];
    let base = run_tra(&op, &[&x, &y], &d, true, 1);
    for threads in [1usize, 2, 8] {
        for owned in [true, false] {
            let got = run_tra(&op, &[&x, &y], &d, owned, threads);
            assert_eq!(got, base, "threads={threads} owned={owned}");
        }
    }
}

#[test]
fn repartition_bytes_tracked_against_cost_model() {
    // The planner charges `cost_repart(need, have, bound)` floats for a
    // repartition edge (whole-tile shipments, §7). The local tile-to-tile
    // implementation moves each float at most once — `bytes_moved` is
    // exactly `4 * prod(bound)` minus the aliased (zero-copy) tiles — so
    // the model's charge must always upper-bound the measured bytes.
    let t = Tensor::random(&[24, 24], 12);
    let cases: &[(&[usize], &[usize])] = &[
        (&[2, 3], &[4, 2]),
        (&[4, 4], &[2, 2]),
        (&[3, 2], &[2, 3]),
        (&[1, 1], &[4, 4]),
        (&[4, 4], &[1, 1]),
        (&[2, 2], &[4, 4]),
    ];
    for &(have, want) in cases {
        let r = TensorRelation::partition(&t, have).unwrap();
        let (r2, stats) = repartition_with_stats(&r, want).unwrap();
        assert_eq!(r2.assemble().unwrap(), t, "{have:?} -> {want:?}");
        let charged_bytes = 4.0 * cost_repart(want, have, &[24, 24]);
        assert!(
            stats.bytes_moved as f64 <= charged_bytes,
            "{have:?} -> {want:?}: moved {} > charged {charged_bytes}",
            stats.bytes_moved
        );
        assert!(stats.bytes_moved <= t.bytes(), "each float moves at most once");
        if stats.tiles_aliased == 0 {
            // no zero-copy tiles: the transfer volume is exactly the
            // tensor — the floor the model's charge bounds.
            assert_eq!(stats.bytes_moved, t.bytes(), "{have:?} -> {want:?}");
        }
    }
    // pure refinement ([1,1] -> anything) aliases everything: zero bytes
    let r = TensorRelation::partition(&t, &[1, 1]).unwrap();
    let (_, stats) = repartition_with_stats(&r, &[4, 4]).unwrap();
    assert_eq!(stats.bytes_moved, 0);
    assert_eq!(stats.tiles_aliased, 16);
}

#[test]
fn pool_reaches_steady_state_no_allocation_growth() {
    // Repeated single-threaded TRA evaluations must stop allocating once
    // the pool is warm: every output/pack buffer of run N+1 is a
    // recycled buffer of run N.
    BufferPool::reset();
    let x = Tensor::random(&[64, 64], 13);
    let y = Tensor::random(&[64, 64], 14);
    let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
    let engine = NativeEngine::new();
    let run = |x: &Tensor, y: &Tensor| {
        let rel = eval_einsum_tra(&op, &[x, y], &[2, 2, 4], &engine).unwrap();
        rel.recycle(); // hand the result tiles back to the pool
    };
    run(&x, &y); // warm-up: allocates
    let warm = BufferPool::stats();
    assert!(warm.misses > 0, "warm-up run should allocate");
    for i in 0..5 {
        run(&x, &y);
        let s = BufferPool::stats();
        assert_eq!(
            s.misses, warm.misses,
            "run {i}: pool missed — live allocations grew in steady state"
        );
    }
    // and the resident set is bounded by what one run uses
    let end = BufferPool::stats();
    assert!(end.resident > 0);
    BufferPool::reset();
}

//! Guard rails for the reproduction's directional claims: small/fast
//! versions of every figure's headline comparison run under `cargo test`,
//! so a regression that flips a paper conclusion fails CI — not just a
//! bench reading.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::models::ffnn::ffnn_step;
use eindecomp::models::llama::{llama_graph, weight_set, LlamaConfig};
use eindecomp::models::matchain::chain_graph;
use eindecomp::sim::memory::{model_with_memory, MemoryConfig, WeightPolicy};
use eindecomp::sim::{Cluster, NetworkProfile};
use eindecomp::taskgraph::TaskKind;

fn roles() -> LabelRoles {
    LabelRoles::by_convention()
}

/// Fig 7/8 headline: on the skewed chain, EinDecomp beats SQRT clearly.
#[test]
fn fig7_skewed_eindecomp_beats_sqrt() {
    let chain = chain_graph(1280, true).unwrap();
    let cluster = Cluster::new(16, NetworkProfile::cpu_cluster());
    let ein = assign(&chain.graph, &Strategy::EinDecomp, 16, &roles()).unwrap();
    let sqrt = assign(&chain.graph, &Strategy::Sqrt, 16, &roles()).unwrap();
    let te = cluster.dry_run(&chain.graph, &ein).unwrap();
    let ts = cluster.dry_run(&chain.graph, &sqrt).unwrap();
    assert!(
        ts.sim_makespan_s > te.sim_makespan_s * 1.5,
        "expected >=1.5x gap: ein {:.6} sqrt {:.6}",
        te.sim_makespan_s,
        ts.sim_makespan_s
    );
    assert!(ts.bytes_moved > te.bytes_moved);
}

/// Fig 7 headline: uniform chain — EinDecomp within ~15% of SQRT (both
/// find near-square decompositions).
#[test]
fn fig7_uniform_parity() {
    let chain = chain_graph(1280, false).unwrap();
    let cluster = Cluster::new(16, NetworkProfile::cpu_cluster());
    let ein = assign(&chain.graph, &Strategy::EinDecomp, 16, &roles()).unwrap();
    let sqrt = assign(&chain.graph, &Strategy::Sqrt, 16, &roles()).unwrap();
    let te = cluster.dry_run(&chain.graph, &ein).unwrap();
    let ts = cluster.dry_run(&chain.graph, &sqrt).unwrap();
    let ratio = te.sim_makespan_s / ts.sim_makespan_s;
    assert!((0.5..1.15).contains(&ratio), "uniform ratio {ratio}");
}

/// Fig 7: the ScaLAPACK proxy (master-distributed inputs) trails SQRT.
#[test]
fn fig7_scalapack_proxy_trails() {
    let chain = chain_graph(1280, false).unwrap();
    let cluster = Cluster::new(16, NetworkProfile::cpu_cluster());
    let sqrt = assign(&chain.graph, &Strategy::Sqrt, 16, &roles()).unwrap();
    let base = cluster.dry_run(&chain.graph, &sqrt).unwrap();
    let mut tg = cluster.lower(&chain.graph, &sqrt).unwrap();
    for t in tg.tasks.iter_mut() {
        if matches!(t.kind, TaskKind::InputTile { .. }) {
            t.worker = Some(0);
        }
    }
    let scal = cluster.model(&tg);
    assert!(scal.sim_makespan_s > base.sim_makespan_s * 1.5);
}

/// Fig 9 headline: data parallelism collapses on the wide FFNN (model
/// broadcast); one device beats 4-way data parallel; EinDecomp beats both.
#[test]
fn fig9_data_parallel_collapses() {
    let step = ffnn_step(128, 32_768, 2048, 4096).unwrap();
    let roles = roles();
    let net = NetworkProfile::gpu_server_p100();
    let four = Cluster::new(4, net.clone());
    let one = Cluster::new(1, net);
    let ein = assign(&step.graph, &Strategy::EinDecomp, 4, &roles).unwrap();
    let dp = assign(&step.graph, &Strategy::DataParallel, 4, &roles).unwrap();
    let t_ein = four.dry_run(&step.graph, &ein).unwrap();
    // weight broadcast: parameters start on worker 0
    let mut tg = four.lower(&step.graph, &dp).unwrap();
    for t in tg.tasks.iter_mut() {
        if let TaskKind::InputTile { vertex, .. } = &t.kind {
            if step.graph.vertex(*vertex).name.starts_with('W') {
                t.worker = Some(0);
            }
        }
    }
    let t_dp = four.model(&tg);
    let dp1 = assign(&step.graph, &Strategy::DataParallel, 1, &roles).unwrap();
    let t_one = one.dry_run(&step.graph, &dp1).unwrap();
    assert!(
        t_one.sim_makespan_s < t_dp.sim_makespan_s,
        "1 device should beat 4-way DP: {:.4} vs {:.4}",
        t_one.sim_makespan_s,
        t_dp.sim_makespan_s
    );
    assert!(
        t_ein.sim_makespan_s < t_dp.sim_makespan_s,
        "eindecomp should beat DP"
    );
    assert!(
        t_ein.sim_makespan_s < t_one.sim_makespan_s,
        "eindecomp on 4 should beat 1 device"
    );
}

/// Fig 10 headline: EinDecomp is the best (or tied-best) decomposition
/// for LLaMA-7B-shaped prefill on 8 GPUs, across batch sizes.
#[test]
fn fig10_eindecomp_wins_llama() {
    let roles = roles();
    let cluster = Cluster::new(8, NetworkProfile::gpu_server_v100());
    for batch in [2usize, 8] {
        let cfg = LlamaConfig {
            layers: 1,
            ..LlamaConfig::llama7b(batch, 1024)
        };
        let model = llama_graph(&cfg).unwrap();
        let mut results = Vec::new();
        for strat in [
            Strategy::EinDecomp,
            Strategy::Megatron,
            Strategy::Sequence,
            Strategy::AttentionHead,
        ] {
            let plan = assign(&model.graph, &strat, 8, &roles).unwrap();
            let rep = cluster.dry_run(&model.graph, &plan).unwrap();
            results.push((strat.name(), rep.sim_makespan_s));
        }
        let ein = results[0].1;
        for (name, t) in &results[1..] {
            assert!(
                ein <= t * 1.05,
                "batch={batch}: eindecomp {ein:.4} vs {name} {t:.4}"
            );
        }
    }
}

/// Fig 11 headline: under the A100 memory budget, Einsummable's policy is
/// at least as fast as ZeRO-like and beats FlexGen-like host streaming.
#[test]
fn fig11_einsummable_leads_offload() {
    let cfg = LlamaConfig {
        layers: 4,
        ..LlamaConfig::llama7b(16, 512)
    };
    let model = llama_graph(&cfg).unwrap();
    let weights = weight_set(&model);
    let net = NetworkProfile::gpu_server_a100();
    let cluster = Cluster::new(8, net.clone());
    let mut t = Vec::new();
    for (strat, policy) in [
        (Strategy::EinDecomp, WeightPolicy::Resident),
        (Strategy::DataParallel, WeightPolicy::ZeroSharded),
        (Strategy::DataParallel, WeightPolicy::HostStreamed),
    ] {
        let plan = assign(&model.graph, &strat, 8, &roles()).unwrap();
        let tg = cluster.lower(&model.graph, &plan).unwrap();
        let mem = MemoryConfig {
            capacity_bytes: 40u64 << 30,
            weight_policy: policy,
        };
        t.push(model_with_memory(&tg, &net, 8, &mem, &weights).sim_makespan_s);
    }
    assert!(t[0] <= t[1] * 1.05, "einsummable {:.4} vs zero {:.4}", t[0], t[1]);
    assert!(t[0] < t[2], "einsummable {:.4} vs flexgen {:.4}", t[0], t[2]);
}

/// §8.1 anchor: the counting formula (already unit-tested) agrees with an
/// actual enumeration at a non-trivial size.
#[test]
fn partitioning_count_formula_vs_enumeration() {
    use eindecomp::decomp::viable::{count_partitionings, viable};
    use eindecomp::einsum::expr::EinSum;
    use eindecomp::einsum::label::labels;
    let op = EinSum::contraction(labels("i j b"), labels("j b k"), labels("i k"));
    // D = 4 unique labels, N = 6 balls
    let ds = viable(&op, &[1 << 12, 1 << 12, 1 << 12, 1 << 12], 64).unwrap();
    assert_eq!(ds.len() as u128, count_partitionings(6, 4));
}

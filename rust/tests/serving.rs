//! Differential and fairness tests for the multi-tenant serving
//! subsystem (`serve::Server`).
//!
//! The load-bearing invariant: a coalesced (batched) execution returns
//! outputs **bitwise-identical** to running each member request solo —
//! across batch sizes (including a padded non-power-of-two), across
//! tenants submitting differently-numbered but canonically equal
//! graphs, and in both real-execution scheduler modes. On top of that:
//! round-robin fair scheduling (a hot tenant cannot starve a cold one)
//! and bounded-queue admission control.

use eindecomp::coordinator::driver::DriverConfig;
use eindecomp::coordinator::session::Session;
use eindecomp::einsum::canon::canonicalize;
use eindecomp::einsum::expr::EinSum;
use eindecomp::einsum::graph::{EinGraph, VertexId};
use eindecomp::einsum::label::labels;
use eindecomp::serve::{ServeConfig, Server, Ticket};
use eindecomp::sim::ExecMode;
use eindecomp::tensor::Tensor;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// A two-matmul chain `Z = (A·B)·C`, with fully renamed labels and a
/// different vertex insertion order when `renamed` — canonically equal
/// to the plain variant but numbered differently, so serving has to
/// bridge the remap when coalescing both into one batch.
fn chain2(renamed: bool, s: usize) -> EinGraph {
    let mut g = EinGraph::new();
    let (li, lj, lk) = if renamed {
        ("p", "q", "r")
    } else {
        ("i", "j", "k")
    };
    let (i, j, k) = (labels(li)[0], labels(lj)[0], labels(lk)[0]);
    let mm = || EinSum::contraction(vec![i, j], vec![j, k], vec![i, k]);
    if renamed {
        let c = g.input("C2", vec![s, s]);
        let a = g.input("A2", vec![s, s]);
        let b = g.input("B2", vec![s, s]);
        let ab = g.add("AB2", mm(), vec![a, b]).unwrap();
        g.add("Z2", mm(), vec![ab, c]).unwrap();
    } else {
        let a = g.input("A", vec![s, s]);
        let b = g.input("B", vec![s, s]);
        let c = g.input("C", vec![s, s]);
        let ab = g.add("AB", mm(), vec![a, b]).unwrap();
        g.add("Z", mm(), vec![ab, c]).unwrap();
    }
    g
}

fn inputs_for(g: &EinGraph, seed: u64) -> HashMap<VertexId, Tensor> {
    g.inputs()
        .into_iter()
        .enumerate()
        .map(|(i, v)| (v, Tensor::random(&g.vertex(v).bound, seed + i as u64)))
        .collect()
}

fn session_with(mode: ExecMode) -> Arc<Session> {
    Arc::new(
        Session::new(DriverConfig {
            workers: 2,
            p: 2,
            exec_mode: mode,
            ..Default::default()
        })
        .unwrap(),
    )
}

fn assert_bitwise_eq(got: &HashMap<VertexId, Tensor>, want: &HashMap<VertexId, Tensor>) {
    assert_eq!(got.len(), want.len(), "output vertex sets differ");
    for (v, w) in want {
        let t = got.get(v).expect("missing output vertex");
        assert_eq!(t.shape(), w.shape(), "output {v} shape differs");
        let eq = t
            .data()
            .iter()
            .zip(w.data())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(eq, "output {v} differs bitwise from the solo run");
    }
}

/// Batched executions are bitwise-identical to solo runs for batch
/// sizes {1, 2, 4, 7} (7 exercises zero-padding up to class 8), with
/// members alternating between two differently-numbered canonical
/// twins, in both scheduler modes.
#[test]
fn batched_bitwise_identical_to_solo_across_sizes_and_modes() {
    let ga = chain2(false, 16);
    let gb = chain2(true, 16);
    assert_eq!(
        canonicalize(&ga).signature,
        canonicalize(&gb).signature,
        "test premise: the two variants must be canonically equal"
    );
    for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
        let session = session_with(mode);
        let exe_a = session.compile(&ga).unwrap();
        let exe_b = session.compile(&gb).unwrap();
        assert_eq!(
            exe_a.artifact_key(),
            exe_b.artifact_key(),
            "canonical twins must share one plan-cache artifact"
        );
        for k in [1usize, 2, 4, 7] {
            let server = Server::with_session(
                Arc::clone(&session),
                ServeConfig {
                    serve_workers: 1,
                    max_batch: 8,
                    batch_window: Duration::from_millis(100),
                    autostart: false,
                    ..Default::default()
                },
            );
            // solo references + staged submissions, request r uses
            // variant r % 2 and its own seeded inputs
            let mut refs = Vec::with_capacity(k);
            let mut tickets: Vec<Ticket> = Vec::with_capacity(k);
            for r in 0..k {
                let (g, exe) = if r % 2 == 0 {
                    (&ga, &exe_a)
                } else {
                    (&gb, &exe_b)
                };
                let inputs = inputs_for(g, 100 + r as u64);
                let (solo, _) = exe.run(&inputs).unwrap();
                refs.push(solo);
                tickets.push(
                    server
                        .submit(&format!("tenant-{}", r % 3), g, inputs)
                        .unwrap(),
                );
            }
            assert_eq!(server.queue_depth(), k);
            server.start();
            for (r, t) in tickets.into_iter().enumerate() {
                let resp = t.wait().unwrap();
                assert_eq!(
                    resp.report.batched_with, k,
                    "mode {mode:?}, k={k}: wrong coalesced size"
                );
                assert!(resp.report.queue_wait_s >= 0.0);
                assert_bitwise_eq(&resp.outputs, &refs[r]);
            }
            let stats = server.serve_stats();
            assert_eq!(stats.completed, k as u64);
            assert_eq!(stats.rejected, 0);
            if k > 1 {
                assert_eq!(stats.batches, 1, "staged queue must coalesce once");
                assert_eq!(stats.batched_requests, k as u64);
                assert_eq!(server.twin_cache_entries(), 1);
            } else {
                assert_eq!(stats.batches, 0);
            }
        }
        // the batcher never re-ran the planner: one solo plan total
        // (twins compile through Session::compile_with_plan)
        assert_eq!(session.stats().planner_runs, 1, "mode {mode:?}");
    }
}

/// Round-robin fair scheduling: with one serving worker and batching
/// off, a cold tenant's 4 requests interleave with a hot tenant's 12
/// instead of waiting behind them. Execution sequence numbers make the
/// order observable and (with a staged queue) deterministic.
#[test]
fn cold_tenant_does_not_starve_behind_hot_tenant() {
    let g = chain2(false, 12);
    let server = Server::with_session(
        session_with(ExecMode::WorkStealing),
        ServeConfig {
            serve_workers: 1,
            max_batch: 1,
            autostart: false,
            ..Default::default()
        },
    );
    let hot: Vec<Ticket> = (0..12)
        .map(|r| server.submit("hot", &g, inputs_for(&g, r)).unwrap())
        .collect();
    let cold: Vec<Ticket> = (0..4)
        .map(|r| server.submit("cold", &g, inputs_for(&g, 50 + r)).unwrap())
        .collect();
    server.start();
    let hot_seqs: Vec<u64> = hot.into_iter().map(|t| t.wait().unwrap().seq).collect();
    let cold_seqs: Vec<u64> = cold.into_iter().map(|t| t.wait().unwrap().seq).collect();
    let cold_max = *cold_seqs.iter().max().unwrap();
    let hot_max = *hot_seqs.iter().max().unwrap();
    assert!(
        cold_max < hot_max,
        "cold tenant finished at seq {cold_max}, after hot's last {hot_max}"
    );
    // strict round-robin: cold's 4 requests all execute within the
    // first 2*4 executions
    assert!(
        cold_max <= 7,
        "cold tenant starved: last request executed at seq {cold_max}"
    );
}

/// Admission control under a full queue: typed rejection, accurate
/// depth, and a clean drain once started.
#[test]
fn bounded_queue_rejects_then_drains() {
    let g = chain2(false, 12);
    let server = Server::with_session(
        session_with(ExecMode::WorkStealing),
        ServeConfig {
            serve_workers: 2,
            max_batch: 8,
            max_queue_depth: 3,
            autostart: false,
            ..Default::default()
        },
    );
    let tickets: Vec<Ticket> = (0..3)
        .map(|r| {
            server
                .submit(&format!("t{r}"), &g, inputs_for(&g, r))
                .unwrap()
        })
        .collect();
    let err = server.submit("t3", &g, inputs_for(&g, 9)).unwrap_err();
    assert!(err.is_queue_full(), "{err}");
    server.start();
    for t in tickets {
        t.wait().unwrap();
    }
    let stats = server.serve_stats();
    assert_eq!(stats.completed, 3);
    assert_eq!(stats.rejected, 1);
    assert_eq!(server.queue_depth(), 0);
}

/// Concurrent tenants over one shared session: every response matches
/// its solo reference even when batches form nondeterministically under
/// live load, and the compile cache planned only once.
#[test]
fn live_load_stays_bitwise_identical() {
    let ga = chain2(false, 16);
    let gb = chain2(true, 16);
    let session = session_with(ExecMode::WorkStealing);
    let exe_a = session.compile(&ga).unwrap();
    let exe_b = session.compile(&gb).unwrap();
    let server = Server::with_session(
        Arc::clone(&session),
        ServeConfig {
            serve_workers: 2,
            max_batch: 4,
            batch_window: Duration::from_millis(2),
            ..Default::default()
        },
    );
    std::thread::scope(|scope| {
        for c in 0..4usize {
            let (server, ga, gb, exe_a, exe_b) = (&server, &ga, &gb, &exe_a, &exe_b);
            scope.spawn(move || {
                for i in 0..6usize {
                    let (g, exe) = if (c + i) % 2 == 0 {
                        (ga, exe_a)
                    } else {
                        (gb, exe_b)
                    };
                    let inputs = inputs_for(g, (c * 31 + i) as u64);
                    let (want, _) = exe.run(&inputs).unwrap();
                    let resp = server.run(&format!("tenant-{c}"), g, inputs).unwrap();
                    assert!(resp.report.batched_with >= 1);
                    assert_bitwise_eq(&resp.outputs, &want);
                }
            });
        }
    });
    let stats = server.serve_stats();
    assert_eq!(stats.completed, 24);
    assert_eq!(stats.rejected, 0);
    assert_eq!(session.stats().planner_runs, 1);
}

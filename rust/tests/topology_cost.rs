//! Property tests for the hierarchical topology cost layer.
//!
//! Four contracts:
//!
//! * [`Topology::link_class`] is a true lowest-common-ancestor lookup:
//!   on randomized valid span trees it matches an independent
//!   brute-force reimplementation, is symmetric, respects nesting
//!   monotonicity, and satisfies the ultrametric inequality a tree
//!   metric must;
//! * a flat topology (and `None`) reproduces the seed §7 repartition
//!   cost model *exactly* — bitwise `f64` equality, including the
//!   paper's worked 320- and 240-float examples;
//! * the collective cost formulas match the textbook ring / tree
//!   byte-and-step counts;
//! * for the same plan, a hierarchical preset topology never costs
//!   more than flat (inner links are at least as fast, so the model
//!   may only discount).

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::decomp::cost::{
    cost_repart, cost_repart_on, cost_ring_allreduce, cost_ring_collective, ring_steps,
    tree_depth,
};
use eindecomp::models::ffnn::ffnn_step;
use eindecomp::models::matchain::chain_graph;
use eindecomp::sim::{LinkClass, NetworkProfile, Topology};
use eindecomp::util::Rng;

/// A random *valid* span tree: 1..=4 levels, each span a multiple of
/// the previous, worker count within the outermost span.
fn random_topology(rng: &mut Rng) -> Topology {
    let levels = 1 + rng.next_below(4);
    let mut spans = Vec::with_capacity(levels);
    let mut span = 1 + rng.next_below(4);
    for _ in 0..levels {
        spans.push(span);
        span *= 2 + rng.next_below(3); // next level nests 2..=4 groups
    }
    let workers = 1 + rng.next_below(*spans.last().unwrap());
    // make sure the outermost span covers every worker (Topology::new
    // invariant); inner spans need no relation to `workers`
    let base_bw = 1e9;
    let classes: Vec<LinkClass> = (0..levels)
        .map(|i| LinkClass {
            name: format!("level{i}"),
            // inner levels faster — same shape as the presets
            bandwidth_bps: base_bw * (1 << (levels - 1 - i)) as f64,
            latency_s: 1e-6 * (i + 1) as f64,
        })
        .collect();
    Topology::new("random", workers, spans, classes)
}

/// Independent LCA reimplementation: the innermost level whose groups
/// contain both workers, else the outermost class.
fn brute_force_lca(spans: &[usize], levels: usize, a: usize, b: usize) -> Option<usize> {
    if a == b {
        return None;
    }
    for (i, &s) in spans.iter().enumerate() {
        if a / s == b / s {
            return Some(i);
        }
    }
    Some(levels - 1)
}

#[test]
fn lca_lookup_matches_brute_force_on_random_trees() {
    let mut rng = Rng::seed_from_u64(0x70_70_10);
    for _ in 0..200 {
        let topo = random_topology(&mut rng);
        let w = topo.workers();
        for _ in 0..50 {
            let a = rng.next_below(w);
            let b = rng.next_below(w);
            let got = topo.link_class(a, b);
            let want = brute_force_lca(topo.spans(), topo.levels(), a, b);
            assert_eq!(got, want, "{:?} workers {a},{b}", topo.spans());
            // symmetry
            assert_eq!(got, topo.link_class(b, a));
            // link_of agrees with link_class
            assert_eq!(
                topo.link_of(a, b).map(|c| c.name.clone()),
                got.map(|i| topo.classes()[i].name.clone())
            );
        }
    }
}

#[test]
fn lca_lookup_is_an_ultrametric_on_random_trees() {
    // Tree distances are ultrametric: d(a,c) <= max(d(a,b), d(b,c)).
    // Violations would mean a transfer can be charged at a *slower*
    // class than any path through an intermediate worker — nonsense
    // for a nesting hierarchy.
    let mut rng = Rng::seed_from_u64(0x70_70_20);
    for _ in 0..100 {
        let topo = random_topology(&mut rng);
        let w = topo.workers();
        for _ in 0..60 {
            let (a, b, c) = (rng.next_below(w), rng.next_below(w), rng.next_below(w));
            if a == b || b == c || a == c {
                continue;
            }
            let ac = topo.link_class(a, c).unwrap();
            let ab = topo.link_class(a, b).unwrap();
            let bc = topo.link_class(b, c).unwrap();
            assert!(
                ac <= ab.max(bc),
                "ultrametric violated on {:?}: d({a},{c})={ac} > max({ab},{bc})",
                topo.spans()
            );
            // monotone nesting: sharing a level-i group caps the class
            for (i, &s) in topo.spans().iter().enumerate() {
                if a / s == b / s {
                    assert!(ab <= i);
                }
            }
        }
    }
}

#[test]
fn flat_topology_is_bytewise_the_seed_cost_model() {
    let net = NetworkProfile::cpu_cluster();
    // the paper's worked §7 examples, pinned
    assert_eq!(cost_repart(&[4, 1], &[2, 4], &[8, 8]), 320.0);
    assert_eq!(cost_repart(&[2, 2], &[4, 4], &[8, 8]), 240.0);
    let mut rng = Rng::seed_from_u64(0x70_70_30);
    for trial in 0..300 {
        let dims = 1 + rng.next_below(3);
        let d_x: Vec<usize> = (0..dims).map(|_| 1 + rng.next_below(5)).collect();
        let d_z: Vec<usize> = (0..dims).map(|_| 1 + rng.next_below(5)).collect();
        let bound: Vec<usize> = (0..dims).map(|_| 1 + rng.next_below(16)).collect();
        let seed_cost = cost_repart(&d_x, &d_z, &bound);
        // exact f64 equality, not approximate: None and flat MUST be
        // the seed model byte for byte
        assert_eq!(
            cost_repart_on(None, &d_x, &d_z, &bound),
            seed_cost,
            "trial {trial}: None diverged for {d_x:?} <- {d_z:?} over {bound:?}"
        );
        for workers in [1usize, 2, 8, 16] {
            let flat = Topology::flat_of(&net, workers);
            assert_eq!(
                cost_repart_on(Some(&flat), &d_x, &d_z, &bound),
                seed_cost,
                "trial {trial}: flat({workers}) diverged for {d_x:?} <- {d_z:?}"
            );
        }
    }
}

#[test]
fn collective_formulas_match_textbook_byte_and_step_counts() {
    let mut rng = Rng::seed_from_u64(0x70_70_40);
    for _ in 0..100 {
        let n = (1 + rng.next_below(1 << 20)) as f64;
        let p = 1 + rng.next_below(64);
        // ring all-gather / reduce-scatter: (p-1)/p * n
        let ring = cost_ring_collective(n, p);
        if p == 1 {
            assert_eq!(ring, 0.0);
        } else {
            assert!((ring - (p as f64 - 1.0) / p as f64 * n).abs() < 1e-9);
            // strictly less than the naive p-1 full-tensor broadcast
            assert!(ring < (p as f64 - 1.0) * n);
        }
        // ring all-reduce = reduce-scatter + all-gather
        assert_eq!(cost_ring_allreduce(n, p), 2.0 * ring);
        // ring serializes p-1 steps
        assert_eq!(ring_steps(p), p - 1);
        // tree depth is the minimal d with arity^d >= p
        for arity in [2usize, 3, 4, 8] {
            let d = tree_depth(p, arity);
            if p > 1 {
                assert!((arity as u64).pow(d as u32) >= p as u64);
                assert!((arity as u64).pow(d as u32 - 1) < p as u64);
            } else {
                assert_eq!(d, 0);
            }
        }
    }
    // spot values
    assert_eq!(cost_ring_collective(1024.0, 8), 896.0);
    assert_eq!(cost_ring_allreduce(1024.0, 8), 1792.0);
    assert_eq!(tree_depth(8, 2), 3);
    assert_eq!(tree_depth(9, 2), 4);
}

#[test]
fn hierarchical_plan_never_costlier_than_flat_for_same_plan() {
    let roles = LabelRoles::by_convention();
    let net = NetworkProfile::cpu_cluster();
    let chain = chain_graph(32, false).unwrap().graph;
    let ffnn = ffnn_step(32, 48, 24, 8).unwrap().graph;
    for (name, g) in [("matchain", &chain), ("ffnn", &ffnn)] {
        for p in [2usize, 4, 8] {
            let plan = assign(g, &Strategy::EinDecomp, p, &roles).unwrap();
            let flat_cost = plan.total_cost(g).unwrap();
            assert_eq!(
                plan.total_cost_on(g, Some(&Topology::flat_of(&net, p))).unwrap(),
                flat_cost,
                "{name} p={p}: flat total_cost_on must equal the seed total_cost"
            );
            for topo in [
                Topology::two_level_of(&net, p),
                Topology::three_level_of(&net, p),
            ] {
                let hier = plan.total_cost_on(g, Some(&topo)).unwrap();
                assert!(
                    hier <= flat_cost + 1e-9,
                    "{name} p={p} {}: hierarchical cost {hier} exceeds flat {flat_cost}",
                    topo.name()
                );
                assert!(hier.is_finite() && hier >= 0.0);
            }
        }
    }
}

#[test]
fn random_d_sweep_hierarchical_repart_never_exceeds_flat() {
    let net = NetworkProfile::cpu_cluster();
    let mut rng = Rng::seed_from_u64(0x70_70_50);
    for trial in 0..200 {
        let dims = 1 + rng.next_below(3);
        let d_x: Vec<usize> = (0..dims).map(|_| 1 + rng.next_below(5)).collect();
        let d_z: Vec<usize> = (0..dims).map(|_| 1 + rng.next_below(5)).collect();
        let bound: Vec<usize> = (0..dims).map(|_| 4 + rng.next_below(29)).collect();
        let flat = cost_repart(&d_x, &d_z, &bound);
        for workers in [2usize, 4, 8, 16] {
            for topo in [
                Topology::two_level_of(&net, workers),
                Topology::three_level_of(&net, workers),
            ] {
                let hier = cost_repart_on(Some(&topo), &d_x, &d_z, &bound);
                assert!(
                    hier <= flat + 1e-9 && hier >= 0.0,
                    "trial {trial} {} workers {workers}: {hier} vs flat {flat} \
                     for {d_x:?} <- {d_z:?} over {bound:?}",
                    topo.name()
                );
            }
        }
    }
}

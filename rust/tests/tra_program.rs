//! The TRA-IR differential and pass-behavior suite.
//!
//! Locks in the redesign's contracts:
//!
//! * `from_plan(...).emit_tasks()` with **no passes** reproduces the
//!   frozen direct lowering (`lower_graph_reference`) exactly — same
//!   tasks, deps, bytes, flops — across matchain / FFNN / attention
//!   (LLaMA block) at p ∈ {2, 4}, and the `safe` default pipeline is
//!   task-graph-neutral on top of that;
//! * `alias-refinement-repart` drops refinement-repartition task counts
//!   to zero while execution stays **bitwise**-identical;
//! * `agg-tree` bounds every aggregation task's fan-in by the tree
//!   arity, deterministically, within tolerance of the dense reference;
//! * the serving surface reports the applied passes (`RunReport` JSON,
//!   `Session::explain`).

use eindecomp::coordinator::driver::DriverConfig;
use eindecomp::coordinator::session::Session;
use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::decomp::Plan;
use eindecomp::einsum::expr::{EinSum, UnaryOp};
use eindecomp::einsum::graph::EinGraph;
use eindecomp::einsum::label::labels;
use eindecomp::models::ffnn::ffnn_step;
use eindecomp::models::llama::{llama_graph, LlamaConfig};
use eindecomp::models::matchain::chain_graph;
use eindecomp::runtime::NativeEngine;
use eindecomp::sim::cluster::{Cluster, ExecMode};
use eindecomp::sim::NetworkProfile;
use eindecomp::taskgraph::lower::lower_graph_reference;
use eindecomp::taskgraph::placement::{place, Policy};
use eindecomp::taskgraph::TaskKind;
use eindecomp::tensor::Tensor;
use eindecomp::tra::passes::{PassManager, PassSelector};
use eindecomp::tra::program::from_plan;
use std::collections::HashMap;

fn workload_graphs() -> Vec<(String, EinGraph)> {
    let cfg = LlamaConfig {
        layers: 1,
        batch: 2,
        seq: 16,
        model_dim: 32,
        heads: 2,
        head_dim: 16,
        ffn_dim: 64,
    };
    vec![
        ("matchain".into(), chain_graph(24, false).unwrap().graph),
        ("matchain-skewed".into(), chain_graph(20, true).unwrap().graph),
        ("ffnn".into(), ffnn_step(32, 48, 24, 8).unwrap().graph),
        ("attention-block".into(), llama_graph(&cfg).unwrap().graph),
    ]
}

/// Acceptance: with all passes disabled, the IR path reproduces the
/// direct lowering exactly over matchain/FFNN/attention at p in {2, 4}
/// — and the default `safe` pipeline changes nothing either.
#[test]
fn ir_emission_matches_reference_lowering_differentially() {
    let roles = LabelRoles::by_convention();
    for (name, g) in workload_graphs() {
        for p in [2usize, 4] {
            for strategy in [Strategy::EinDecomp, Strategy::Greedy] {
                let plan = assign(&g, &strategy, p, &roles).unwrap();
                let reference = lower_graph_reference(&g, &plan).unwrap();

                // raw IR, no passes
                let prog = from_plan(&g, &plan).unwrap();
                let emitted = prog.emit_tasks().unwrap();
                assert_eq!(
                    emitted, reference,
                    "{name} p={p} {}: no-pass emission diverged",
                    strategy.name()
                );

                // the default (safe) pipeline is task-graph-neutral
                let mut prog_safe = from_plan(&g, &plan).unwrap();
                PassManager::new(&PassSelector::Safe).run(&mut prog_safe);
                assert_eq!(
                    prog_safe.emit_tasks().unwrap(),
                    reference,
                    "{name} p={p} {}: safe passes changed the task graph",
                    strategy.name()
                );
            }
        }
    }
}

/// Placement on top of identical task graphs is identical too, so the
/// whole `Cluster::lower` pipeline (with default passes) equals
/// reference-lower + place.
#[test]
fn cluster_lower_equals_placed_reference() {
    let roles = LabelRoles::by_convention();
    let g = chain_graph(24, false).unwrap().graph;
    for workers in [2usize, 4] {
        let plan = assign(&g, &Strategy::EinDecomp, workers, &roles).unwrap();
        let cluster = Cluster::new(workers, NetworkProfile::loopback());
        let placed = cluster.lower(&g, &plan).unwrap();
        let mut reference = lower_graph_reference(&g, &plan).unwrap();
        place(&mut reference, workers, Policy::LocalityGreedy);
        assert_eq!(placed, reference);
    }
}

/// A chain whose second vertex needs operand 0 at a pure refinement of
/// the producer's layout: Z1 emits [2,2] tiles, Z2 wants [4,4].
fn refinement_chain() -> (EinGraph, Plan) {
    let mut g = EinGraph::new();
    let a = g.input("A", vec![16, 16]);
    let b = g.input("B", vec![16, 16]);
    let c = g.input("C", vec![16, 16]);
    let z1 = g
        .add(
            "Z1",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    g.add(
        "Z2",
        EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
        vec![z1, c],
    )
    .unwrap();
    let mut plan = Plan::default();
    plan.parts.insert(z1, vec![2, 1, 2]); // dz(Z1) = [2, 2]
    plan.parts.insert(g.by_name("Z2").unwrap(), vec![4, 4, 1]); // needs Z1 as [4, 4]
    plan.finalize_inputs(&g);
    (g, plan)
}

fn repart_count(tg: &eindecomp::taskgraph::TaskGraph) -> usize {
    tg.tasks
        .iter()
        .filter(|t| matches!(t.kind, TaskKind::Repart { .. }))
        .count()
}

/// Acceptance: `alias-refinement-repart` drops refinement-repartition
/// task counts to zero, and execution stays bitwise-identical to the
/// un-aliased pipeline.
#[test]
fn alias_pass_zeroes_refinement_reparts_bitwise() {
    let (g, plan) = refinement_chain();
    let without = from_plan(&g, &plan).unwrap().emit_tasks().unwrap();
    assert_eq!(repart_count(&without), 16, "16 refinement tiles expected");

    let mut prog = from_plan(&g, &plan).unwrap();
    let log = PassManager::new(&PassSelector::All).run(&mut prog);
    let with = prog.emit_tasks().unwrap();
    assert_eq!(repart_count(&with), 0, "aliased reparts must emit no tasks");
    assert!(log
        .entries
        .iter()
        .any(|e| e.pass == "alias-refinement-repart" && e.changes == 1));
    assert_eq!(with.kernel_calls(), without.kernel_calls());

    // execution: bitwise-identical outputs with and without the alias
    let mut inputs = HashMap::new();
    for name in ["A", "B", "C"] {
        let v = g.by_name(name).unwrap();
        inputs.insert(v, Tensor::random(&[16, 16], v.0 as u64 + 40));
    }
    let engine = NativeEngine::new();
    let z2 = g.by_name("Z2").unwrap();
    let base = Cluster::new(4, NetworkProfile::loopback())
        .with_passes(PassSelector::None)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0;
    // alias without the re-associating agg-tree: bitwise guarantee holds
    let aliased = Cluster::new(4, NetworkProfile::loopback())
        .with_passes("elide-identity-repart,alias-refinement-repart".parse().unwrap())
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0;
    assert_eq!(base[&z2], aliased[&z2], "alias pass changed execution bytes");
    // and agrees with the dense reference
    let dense = eindecomp::runtime::native::eval_graph(&g, &inputs).unwrap();
    assert!(aliased[&z2].allclose(&dense[&z2], 1e-4, 1e-5));
}

/// Acceptance: `agg-tree` bounds every aggregation task's fan-in by the
/// tree arity; execution is deterministic (bitwise across runs and
/// executor modes) and matches the dense reference within tolerance.
#[test]
fn agg_tree_bounds_fan_in_and_stays_deterministic() {
    let mut g = EinGraph::new();
    let a = g.input("A", vec![32, 32]);
    let b = g.input("B", vec![32, 32]);
    let z = g
        .add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    let mut plan = Plan::default();
    plan.parts.insert(z, vec![2, 8, 2]); // 8-way aggregation groups
    plan.finalize_inputs(&g);

    let serial = from_plan(&g, &plan).unwrap().emit_tasks().unwrap();
    let serial_max_fanin = serial
        .tasks
        .iter()
        .filter(|t| matches!(t.kind, TaskKind::Agg { .. }))
        .map(|t| t.deps.len())
        .max()
        .unwrap();
    assert_eq!(serial_max_fanin, 8, "serial fold reads the whole group");

    let mut prog = from_plan(&g, &plan).unwrap();
    PassManager::new(&PassSelector::All).run(&mut prog); // default arity 4
    let tree = prog.emit_tasks().unwrap();
    let mut tree_aggs = 0usize;
    for t in &tree.tasks {
        if matches!(t.kind, TaskKind::Agg { .. }) {
            tree_aggs += 1;
            assert!(t.deps.len() <= 4, "fan-in {} exceeds arity 4", t.deps.len());
        }
    }
    // per group of 8 at arity 4: two level-1 folds + one root
    let serial_agg_count = serial
        .tasks
        .iter()
        .filter(|t| matches!(t.kind, TaskKind::Agg { .. }))
        .count();
    assert_eq!(tree_aggs, 3 * serial_agg_count);
    // same total aggregation flops, just re-associated
    let flops = |tg: &eindecomp::taskgraph::TaskGraph| -> f64 {
        tg.tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Agg { .. }))
            .map(|t| t.flops)
            .sum()
    };
    assert_eq!(flops(&serial), flops(&tree));

    // execution through the full pipeline: deterministic + correct
    let mut inputs = HashMap::new();
    inputs.insert(a, Tensor::random(&[32, 32], 91));
    inputs.insert(b, Tensor::random(&[32, 32], 92));
    let engine = NativeEngine::new();
    let dense = eindecomp::runtime::native::eval_graph(&g, &inputs).unwrap();
    let mut first: Option<Tensor> = None;
    for mode in [ExecMode::WorkStealing, ExecMode::WorkStealing, ExecMode::LevelBarrier] {
        let outs = Cluster::new(4, NetworkProfile::loopback())
            .with_passes(PassSelector::All)
            .with_exec_mode(mode)
            .execute(&g, &plan, &engine, &inputs)
            .unwrap()
            .0;
        assert!(outs[&z].allclose(&dense[&z], 1e-4, 1e-5), "{mode:?}");
        match &first {
            None => first = Some(outs[&z].clone()),
            Some(f) => assert_eq!(&outs[&z], f, "{mode:?} not bitwise-deterministic"),
        }
    }
}

/// The serving surface reports the applied pass list and the new ledger
/// fields, and `Session::explain` shows the optimized program.
#[test]
fn session_surfaces_passes_and_explain() {
    let cfg = DriverConfig {
        workers: 2,
        p: 4,
        network: NetworkProfile::loopback(),
        passes: PassSelector::All,
        ..Default::default()
    };
    let session = Session::new(cfg).unwrap();
    let g = chain_graph(24, false).unwrap().graph;
    let exe = session.compile(&g).unwrap();
    assert_eq!(exe.passes().len(), 8);
    exe.task_graph().validate(2).unwrap(); // compile-time validation held

    let mut inputs = HashMap::new();
    for (i, v) in g.inputs().into_iter().enumerate() {
        inputs.insert(v, Tensor::random(&g.vertex(v).bound, 70 + i as u64));
    }
    let (_, rep) = exe.run(&inputs).unwrap();
    let json = rep.to_json().render();
    for key in ["task_count", "bytes_input", "\"passes\"", "agg-tree"] {
        assert!(json.contains(key), "missing {key} in {json}");
    }

    let explain = session.explain(&exe);
    let text = explain.render();
    for needle in ["Join", "Partition", "passes:", "task graph:", "modeled bytes:"] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
}

/// `--passes none` and the IR wrapper agree through the public Cluster
/// API even on plans with coarsening repartitions (not refinements), so
/// the alias pass correctly leaves them alone.
#[test]
fn coarsening_reparts_are_never_aliased() {
    let mut g = EinGraph::new();
    let a = g.input("A", vec![16, 16]);
    let b = g.input("B", vec![16, 16]);
    let c = g.input("C", vec![16, 16]);
    let z1 = g
        .add(
            "Z1",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    let z2 = g
        .add(
            "Z2",
            EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
            vec![z1, c],
        )
        .unwrap();
    let mut plan = Plan::default();
    plan.parts.insert(z1, vec![4, 1, 4]); // dz(Z1) = [4, 4]
    plan.parts.insert(z2, vec![2, 2, 2]); // needs Z1 as [2, 2]: coarsening
    plan.finalize_inputs(&g);
    let mut prog = from_plan(&g, &plan).unwrap();
    let log = PassManager::new(&PassSelector::All).run(&mut prog);
    assert!(log
        .entries
        .iter()
        .all(|e| e.pass != "alias-refinement-repart" || e.changes == 0));
    let tg = prog.emit_tasks().unwrap();
    assert!(repart_count(&tg) > 0, "coarsening must still emit repart tasks");
    // and the lowered graph still executes correctly
    let mut inputs = HashMap::new();
    for name in ["A", "B", "C"] {
        let v = g.by_name(name).unwrap();
        inputs.insert(v, Tensor::random(&[16, 16], v.0 as u64 + 7));
    }
    let engine = NativeEngine::new();
    let outs = Cluster::new(4, NetworkProfile::loopback())
        .with_passes(PassSelector::All)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0;
    let dense = eindecomp::runtime::native::eval_graph(&g, &inputs).unwrap();
    assert!(outs[&z2].allclose(&dense[&z2], 1e-4, 1e-5));
}

/// Tentpole acceptance: `fuse-epilogue` folds a pure map vertex into its
/// producer's kernel epilogue — fewer kernel tasks, and outputs stay
/// bitwise-identical to the unfused pipeline across intra-op sharding
/// degrees (the epilogue applies per whole output tile, outside the
/// sharded GEMM, so the shard count cannot reorder it).
#[test]
fn fused_epilogue_bitwise_across_intra_op_threads() {
    let mut g = EinGraph::new();
    let a = g.input("A", vec![32, 32]);
    let b = g.input("B", vec![32, 32]);
    let z = g
        .add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    let r = g.add("R", EinSum::map(labels("i k"), UnaryOp::Relu), vec![z]).unwrap();
    let mut plan = Plan::default();
    plan.parts.insert(z, vec![2, 1, 2]); // dz(Z) = [2, 2]
    plan.parts.insert(r, vec![2, 2]); // same layout: fusable
    plan.finalize_inputs(&g);

    let unfused = from_plan(&g, &plan).unwrap().emit_tasks().unwrap();
    let mut prog = from_plan(&g, &plan).unwrap();
    let log = PassManager::new(&PassSelector::All).run(&mut prog);
    let fused = prog.emit_tasks().unwrap();
    assert_eq!(
        fused.kernel_calls(),
        unfused.kernel_calls() - 4,
        "R's 4 map kernels must fold into Z's epilogue"
    );
    let entry = log.entries.iter().find(|e| e.pass == "fuse-epilogue").unwrap();
    assert_eq!(entry.changes, 1);
    assert!(entry.tasks_delta < 0, "fusion must drop tasks");
    assert!(!fused.kernel_epilogue.is_empty(), "epilogue hook must be registered");

    let mut inputs = HashMap::new();
    inputs.insert(a, Tensor::random(&[32, 32], 11));
    inputs.insert(b, Tensor::random(&[32, 32], 12));
    let engine = NativeEngine::new();
    let dense = eindecomp::runtime::native::eval_graph(&g, &inputs).unwrap();
    let base = Cluster::new(4, NetworkProfile::loopback())
        .with_passes(PassSelector::None)
        .with_intra_op(1)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0;
    assert!(base[&r].allclose(&dense[&r], 1e-4, 1e-5));
    for intra in [1usize, 2, 8] {
        let outs = Cluster::new(4, NetworkProfile::loopback())
            .with_passes(PassSelector::All)
            .with_intra_op(intra)
            .execute(&g, &plan, &engine, &inputs)
            .unwrap()
            .0;
        assert_eq!(outs[&r], base[&r], "intra_op {intra}: fused epilogue changed bits");
    }
}

/// IR CSE merges duplicate vertex chains into one, halving kernel work;
/// both merged vertices still assemble (shared result tiles are read by
/// each output) and execution stays bitwise-identical.
#[test]
fn cse_merges_duplicate_chains_and_shares_assembly() {
    let mut g = EinGraph::new();
    let a = g.input("A", vec![16, 16]);
    let b = g.input("B", vec![16, 16]);
    let z1 = g
        .add(
            "Z1",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    let z2 = g
        .add(
            "Z2",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    let mut plan = Plan::default();
    plan.parts.insert(z1, vec![2, 2, 2]); // aggregating: terminal is an Aggregate
    plan.parts.insert(z2, vec![2, 2, 2]);
    plan.finalize_inputs(&g);

    let baseline = from_plan(&g, &plan).unwrap().emit_tasks().unwrap();
    let mut prog = from_plan(&g, &plan).unwrap();
    let log = PassManager::new(&PassSelector::All).run(&mut prog);
    let merged = prog.emit_tasks().unwrap();
    assert_eq!(
        merged.kernel_calls() * 2,
        baseline.kernel_calls(),
        "duplicate join kernels must halve"
    );
    let entry = log.entries.iter().find(|e| e.pass == "cse").unwrap();
    assert!(entry.changes > 0);
    assert!(entry.tasks_delta < 0, "cse must drop tasks");
    // both output vertices registered, sharing one tile set
    assert_eq!(merged.vertex_outputs[&z1], merged.vertex_outputs[&z2]);

    let mut inputs = HashMap::new();
    inputs.insert(a, Tensor::random(&[16, 16], 21));
    inputs.insert(b, Tensor::random(&[16, 16], 22));
    let engine = NativeEngine::new();
    let base = Cluster::new(4, NetworkProfile::loopback())
        .with_passes(PassSelector::None)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0;
    let outs = Cluster::new(4, NetworkProfile::loopback())
        .with_passes(PassSelector::All)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0;
    assert_eq!(outs[&z1], base[&z1], "cse changed Z1 bits");
    assert_eq!(outs[&z2], base[&z2], "cse changed Z2 bits");
}

/// Mirrors `canon.rs`'s adversarial named-signature case: same-shape
/// joins whose label roles differ (batch `b` vs sequence `s`) are
/// structurally isomorphic, so structural CSE merges them — but under a
/// label-role-sensitive strategy the merge is wrong, and the
/// label-sensitive manager must leave them alone.
#[test]
fn cse_respects_label_roles_under_named_signatures() {
    let mut g = EinGraph::new();
    let x = g.input("X", vec![16, 8]);
    let w = g.input("W", vec![8, 16]);
    g.add(
        "Zb",
        EinSum::contraction(labels("b j"), labels("j k"), labels("b k")),
        vec![x, w],
    )
    .unwrap();
    g.add(
        "Zs",
        EinSum::contraction(labels("s j"), labels("j k"), labels("s k")),
        vec![x, w],
    )
    .unwrap();
    let mut plan = Plan::default();
    plan.parts.insert(g.by_name("Zb").unwrap(), vec![2, 1, 2]);
    plan.parts.insert(g.by_name("Zs").unwrap(), vec![2, 1, 2]);
    plan.finalize_inputs(&g);

    let mut prog = from_plan(&g, &plan).unwrap();
    let log = PassManager::new(&PassSelector::All).run(&mut prog);
    assert!(
        log.entries.iter().any(|e| e.pass == "cse" && e.changes > 0),
        "structural cse should merge the isomorphic twins"
    );

    let mut prog2 = from_plan(&g, &plan).unwrap();
    let log2 = PassManager::new(&PassSelector::All)
        .with_label_sensitivity(true)
        .run(&mut prog2);
    assert!(
        log2.entries.iter().all(|e| e.pass != "cse" || e.changes == 0),
        "label-sensitive cse must not merge across label roles"
    );
}

/// `propagate-partitions` rewrites a mis-partitioned input to its
/// consumer's needed layout, eliding the repartition chain entirely —
/// the byte win lands on the propagation entry itself (the `Π` becomes
/// identity the moment the layout changes).
#[test]
fn propagation_elides_repart_chains() {
    let mut g = EinGraph::new();
    let a = g.input("A", vec![16, 16]);
    let b = g.input("B", vec![16, 16]);
    let z = g
        .add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    let mut plan = Plan::default();
    plan.parts.insert(z, vec![4, 1, 1]); // A needed as [4,1], B as [1,1]
    // deliberately mis-partitioned: A split along the wrong axis
    plan.input_parts.insert(a, vec![1, 4]);
    plan.input_parts.insert(b, vec![1, 1]);

    let baseline = from_plan(&g, &plan).unwrap().emit_tasks().unwrap();
    assert_eq!(repart_count(&baseline), 4, "mis-partitioned A needs 4 repart tiles");

    let mut prog = from_plan(&g, &plan).unwrap();
    let log = PassManager::new(&PassSelector::All).run(&mut prog);
    let tuned = prog.emit_tasks().unwrap();
    assert_eq!(repart_count(&tuned), 0, "propagated layout must elide all reparts");
    let entry = log
        .entries
        .iter()
        .find(|e| e.pass == "propagate-partitions")
        .unwrap();
    assert_eq!(entry.changes, 1, "only A needs rewriting");
    assert!(entry.tasks_delta < 0);
    assert!(entry.repart_bytes_delta < 0);

    // execution agrees bitwise (the executor slices inputs by the
    // emitted layout, not the plan's)
    let mut inputs = HashMap::new();
    inputs.insert(a, Tensor::random(&[16, 16], 31));
    inputs.insert(b, Tensor::random(&[16, 16], 32));
    let engine = NativeEngine::new();
    let base = Cluster::new(4, NetworkProfile::loopback())
        .with_passes(PassSelector::None)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0;
    let outs = Cluster::new(4, NetworkProfile::loopback())
        .with_passes(PassSelector::All)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0;
    assert_eq!(outs[&z], base[&z], "propagation changed execution bits");
}

/// Regression for the zero-byte cost-model fix, pinned at the ledger
/// level: a fully-aliased refinement chain moves zero modeled repart
/// bytes, and zero-byte transfers cost exactly zero seconds even on a
/// latency-bearing profile.
#[test]
fn alias_refinement_ledger_is_free() {
    let (g, plan) = refinement_chain();
    let net = NetworkProfile::cpu_cluster();
    assert!(net.latency_s > 0.0);
    assert_eq!(net.wire_s(0), 0.0, "zero bytes must cost zero seconds");
    assert_eq!(net.host_s(0), 0.0);
    let sel: PassSelector = "elide-identity-repart,alias-refinement-repart".parse().unwrap();
    let rep = Cluster::new(4, net)
        .with_passes(sel)
        .dry_run(&g, &plan)
        .unwrap();
    assert_eq!(rep.bytes_repart, 0, "aliased reparts move no modeled bytes");
}

//! Differential suite for fault-injected execution and lineage-based
//! recovery.
//!
//! The recovery contract is *bitwise* equivalence: tasks are pure
//! functions of their dependency tiles and every fold order is fixed by
//! the task graph, so recomputing a reclaimed tile reproduces its exact
//! bytes. This suite locks that in:
//!
//! * every bench workload (matrix chain, FFNN training step, one-layer
//!   attention), for p in {2, 4, 8}, in BOTH real-execution modes,
//!   survives a single injected fault at EVERY task index
//!   (parity-alternating transient/permanent, plus a full both-kinds
//!   sweep on the chain at p = 4) with outputs bitwise-identical to the
//!   fault-free run and non-vacuous retry/recompute counters;
//! * seeded multi-fault runs are deterministic and bitwise-clean;
//! * a zero deadline returns a typed `DeadlineExceeded` error promptly,
//!   with partial-progress stats attached;
//! * a fault-free run reports zero recovery overhead and a ledger
//!   identical to the precomputed model.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::einsum::graph::{EinGraph, VertexId};
use eindecomp::error::ExecCause;
use eindecomp::models::ffnn::ffnn_step;
use eindecomp::models::llama::{llama_graph, LlamaConfig};
use eindecomp::models::matchain::chain_graph;
use eindecomp::runtime::NativeEngine;
use eindecomp::sim::{Cluster, ExecMode, FaultPlan, NetworkProfile, RunOptions};
use eindecomp::tensor::Tensor;
use std::collections::HashMap;
use std::time::Duration;

fn random_inputs(g: &EinGraph, seed: u64) -> HashMap<VertexId, Tensor> {
    g.inputs()
        .into_iter()
        .enumerate()
        .map(|(i, v)| (v, Tensor::random(&g.vertex(v).bound, seed + i as u64)))
        .collect()
}

/// Zero-backoff options so exhaustive sweeps do not sleep between
/// retries (retry counting and recovery behaviour are unaffected; only
/// the stall charge collapses to zero).
fn fast_retries() -> RunOptions {
    RunOptions {
        backoff_base: Duration::ZERO,
        backoff_cap: Duration::ZERO,
        ..Default::default()
    }
}

/// The exhaustive single-fault sweep for one workload: for every p and
/// both exec modes, inject exactly one fault at every task index and
/// require bitwise-identical outputs plus truthful counters. `kinds_at`
/// picks which fault kinds to exercise at a given task index. Returns
/// `(total_retries, total_recomputed)` so callers can assert the sweep
/// was not vacuous.
fn sweep_single_faults(
    name: &str,
    g: &EinGraph,
    ps: &[usize],
    kinds_at: fn(usize) -> &'static [bool],
) -> (u64, u64) {
    let engine = NativeEngine::new();
    let roles = LabelRoles::by_convention();
    let opts = fast_retries();
    let mut total_retries = 0u64;
    let mut total_recomputed = 0u64;
    for &p in ps {
        let plan = assign(g, &Strategy::EinDecomp, p, &roles).unwrap();
        let inputs = random_inputs(g, 700 + p as u64);
        // Lower + model once per (workload, p): the frozen task graph is
        // reusable across every faulted run (compile-once / run-many).
        let base_cluster = Cluster::new(p, NetworkProfile::loopback());
        let tg = base_cluster.lower(g, &plan).unwrap();
        let model = base_cluster.model(&tg);
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            let clean_cluster =
                Cluster::new(p, NetworkProfile::loopback()).with_exec_mode(mode);
            let (clean, clean_rep) = clean_cluster
                .run_lowered_modeled_opts(g, &plan, &tg, &model, &engine, &inputs, &opts)
                .unwrap();
            assert_eq!(clean_rep.faults_injected, 0, "{name} p={p} {mode:?}");
            assert_eq!(clean_rep.retries, 0, "{name} p={p} {mode:?}");
            for ti in 0..tg.tasks.len() {
                for &permanent in kinds_at(ti) {
                    let fp = if permanent {
                        FaultPlan::new().permanent(ti)
                    } else {
                        FaultPlan::new().transient(ti, 1)
                    };
                    let cluster = Cluster::new(p, NetworkProfile::loopback())
                        .with_exec_mode(mode)
                        .with_faults(fp);
                    let (outs, rep) = cluster
                        .run_lowered_modeled_opts(g, &plan, &tg, &model, &engine, &inputs, &opts)
                        .unwrap();
                    let tag = format!(
                        "{name} p={p} {mode:?} task {ti} {}",
                        if permanent { "permanent" } else { "transient" }
                    );
                    for out in g.outputs() {
                        assert_eq!(
                            clean[&out], outs[&out],
                            "{tag}: recovery diverged bitwise from the fault-free run"
                        );
                    }
                    assert_eq!(rep.faults_injected, 1, "{tag}");
                    assert!(rep.retries >= 1, "{tag}: fault recovered without a retry");
                    if permanent {
                        assert_eq!(rep.workers_lost, 1, "{tag}");
                    } else {
                        assert_eq!(rep.workers_lost, 0, "{tag}");
                        assert_eq!(rep.recovery_bytes, 0, "{tag}: transient faults move no bytes");
                    }
                    total_retries += rep.retries;
                    total_recomputed += rep.recomputed_tasks;
                }
            }
        }
    }
    (total_retries, total_recomputed)
}

/// Parity-alternating kind choice: even task ids take a transient fault,
/// odd ones a permanent worker death — every index is hit, both kinds
/// are exercised across the sweep.
fn parity(ti: usize) -> &'static [bool] {
    if ti % 2 == 0 {
        &[false]
    } else {
        &[true]
    }
}

/// Both kinds at every index — the full cross product.
fn both(_ti: usize) -> &'static [bool] {
    &[false, true]
}

#[test]
fn matchain_exhaustive_both_kinds_p4() {
    let chain = chain_graph(24, false).unwrap();
    let (retries, recomputed) = sweep_single_faults("matchain", &chain.graph, &[4], both);
    assert!(retries > 0, "sweep never retried (vacuous)");
    assert!(recomputed > 0, "no worker death ever forced a lineage recompute");
}

#[test]
fn matchain_single_fault_every_index() {
    let chain = chain_graph(24, false).unwrap();
    let (retries, recomputed) = sweep_single_faults("matchain", &chain.graph, &[2, 4, 8], parity);
    assert!(retries > 0, "sweep never retried (vacuous)");
    assert!(recomputed > 0, "no worker death ever forced a lineage recompute");
}

#[test]
fn ffnn_single_fault_every_index() {
    let ffnn = ffnn_step(32, 48, 24, 8).unwrap();
    let (retries, _) = sweep_single_faults("ffnn", &ffnn.graph, &[2, 4, 8], parity);
    assert!(retries > 0, "sweep never retried (vacuous)");
}

#[test]
fn attention_single_fault_every_index() {
    let cfg = LlamaConfig {
        layers: 1,
        batch: 2,
        seq: 16,
        model_dim: 32,
        heads: 2,
        head_dim: 16,
        ffn_dim: 64,
    };
    let attn = llama_graph(&cfg).unwrap();
    let (retries, _) = sweep_single_faults("attention", &attn.graph, &[2, 4, 8], parity);
    assert!(retries > 0, "sweep never retried (vacuous)");
}

#[test]
fn seeded_multi_fault_runs_are_deterministic_and_bitwise() {
    let chain = chain_graph(24, false).unwrap();
    let g = &chain.graph;
    let engine = NativeEngine::new();
    let roles = LabelRoles::by_convention();
    let opts = fast_retries();
    let plan = assign(g, &Strategy::EinDecomp, 4, &roles).unwrap();
    let inputs = random_inputs(g, 1300);
    let base_cluster = Cluster::new(4, NetworkProfile::loopback());
    let tg = base_cluster.lower(g, &plan).unwrap();
    let model = base_cluster.model(&tg);
    let (clean, _) = base_cluster
        .run_lowered_modeled_opts(g, &plan, &tg, &model, &engine, &inputs, &opts)
        .unwrap();
    let mut any_fault = false;
    for seed in [7u64, 23, 91] {
        // fault arming is a pure function of (seed, rate, task count):
        // both exec modes must inject the same fault count
        let mut injected_by_mode = Vec::new();
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            let cluster = Cluster::new(4, NetworkProfile::loopback())
                .with_exec_mode(mode)
                .with_faults(FaultPlan::new().seeded(seed, 0.3));
            let (outs, rep) = cluster
                .run_lowered_modeled_opts(g, &plan, &tg, &model, &engine, &inputs, &opts)
                .unwrap();
            for out in g.outputs() {
                assert_eq!(clean[&out], outs[&out], "seed {seed} {mode:?}");
            }
            assert!(rep.retries >= rep.faults_injected, "seed {seed} {mode:?}");
            injected_by_mode.push(rep.faults_injected);
            any_fault |= rep.faults_injected > 0;
        }
        assert_eq!(
            injected_by_mode[0], injected_by_mode[1],
            "seed {seed}: injected fault count must be schedule-independent"
        );
    }
    assert!(any_fault, "rate 0.3 across three seeds never armed a fault (vacuous)");
}

#[test]
fn deadline_exceeded_is_typed_and_prompt() {
    let chain = chain_graph(24, false).unwrap();
    let g = &chain.graph;
    let engine = NativeEngine::new();
    let roles = LabelRoles::by_convention();
    let plan = assign(g, &Strategy::EinDecomp, 4, &roles).unwrap();
    let inputs = random_inputs(g, 1700);
    let cluster = Cluster::new(4, NetworkProfile::loopback());
    let opts = RunOptions {
        deadline: Some(Duration::ZERO),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let err = cluster
        .execute_opts(g, &plan, &engine, &inputs, &opts)
        .unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "deadline error took {:?} to surface",
        t0.elapsed()
    );
    assert!(err.is_deadline(), "{err}");
    match &err.as_exec().unwrap().cause {
        ExecCause::DeadlineExceeded { total, completed, .. } => {
            assert!(*total > 0);
            assert!(completed <= total);
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
}

#[test]
fn fault_free_run_reports_zero_recovery_overhead() {
    let chain = chain_graph(24, false).unwrap();
    let g = &chain.graph;
    let engine = NativeEngine::new();
    let roles = LabelRoles::by_convention();
    let plan = assign(g, &Strategy::EinDecomp, 4, &roles).unwrap();
    let inputs = random_inputs(g, 2100);
    let cluster = Cluster::new(4, NetworkProfile::loopback());
    let tg = cluster.lower(g, &plan).unwrap();
    let model = cluster.model(&tg);
    let (first, rep) = cluster
        .run_lowered(g, &plan, &tg, &engine, &inputs)
        .unwrap();
    // zero recovery overhead, ledger identical to the precomputed model
    assert_eq!(rep.faults_injected, 0);
    assert_eq!(rep.retries, 0);
    assert_eq!(rep.recomputed_tasks, 0);
    assert_eq!(rep.recovery_bytes, 0);
    assert_eq!(rep.workers_lost, 0);
    assert_eq!(rep.recovery_stall_s, 0.0);
    assert!(rep.recovery_by_link.is_empty());
    assert_eq!(rep.sim_makespan_s, model.sim_makespan_s);
    assert_eq!(rep.bytes_moved, model.bytes_moved);
    assert_eq!(rep.bytes_repart, model.bytes_repart);
    // and bitwise-reproducible across calls
    let (second, _) = cluster
        .run_lowered(g, &plan, &tg, &engine, &inputs)
        .unwrap();
    for out in g.outputs() {
        assert_eq!(first[&out], second[&out]);
    }
}

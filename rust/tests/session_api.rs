//! The compile-once / run-many `Session` API, differentially against the
//! legacy `Driver` path:
//!
//! * Session outputs are **bitwise-identical** to `Driver::run` on the
//!   matchain / FFNN / attention graphs (same planner, same lowered task
//!   graph, same deterministic executor);
//! * the plan cache hits on a label-renamed + vertex-reordered clone of a
//!   compiled graph (and the hit's vertex remap is numerically correct),
//!   and misses on a shape change;
//! * `Executable::run` does zero planner / zero lowering work after
//!   `compile` — asserted via the session's plan-cache stats — and
//!   repeated runs are bitwise-identical with `cache_hit` provenance on
//!   recompiles.

use eindecomp::coordinator::driver::{Driver, DriverConfig, PlanProvenance};
use eindecomp::coordinator::session::Session;
use eindecomp::einsum::expr::{EinSum, JoinOp};
use eindecomp::einsum::graph::{EinGraph, VertexId};
use eindecomp::einsum::label::labels;
use eindecomp::einsum::macros::multihead_attention;
use eindecomp::models::ffnn::{ffnn_step, step_inputs, FfnnState};
use eindecomp::models::matchain::{chain_graph, chain_inputs};
use eindecomp::runtime::native::eval_graph;
use eindecomp::runtime::Backend;
use eindecomp::sim::NetworkProfile;
use eindecomp::tensor::Tensor;
use std::collections::HashMap;

fn cfg(workers: usize) -> DriverConfig {
    DriverConfig {
        workers,
        p: workers,
        backend: Backend::Native,
        network: NetworkProfile::loopback(),
        ..Default::default()
    }
}

/// Driver (plan-per-call) and Session (compile once) must produce
/// bitwise-identical outputs for the same graph + inputs.
fn assert_session_matches_driver(g: &EinGraph, inputs: &HashMap<VertexId, Tensor>) {
    let driver = Driver::new(cfg(4)).unwrap();
    let (outs_d, rep_d) = driver.run(g, inputs).unwrap();
    assert_eq!(rep_d.provenance, PlanProvenance::Planned);

    let session = Session::new(cfg(4)).unwrap();
    let exe = session.compile(g).unwrap();
    let (outs_s, rep_s) = exe.run(inputs).unwrap();
    assert_eq!(rep_s.provenance, PlanProvenance::Planned);
    assert_eq!(outs_d, outs_s);
}

#[test]
fn session_matches_driver_bitwise_matchain() {
    for skewed in [false, true] {
        let chain = chain_graph(40, skewed).unwrap();
        let inputs = chain_inputs(&chain, 11);
        assert_session_matches_driver(&chain.graph, &inputs);
    }
}

#[test]
fn session_matches_driver_bitwise_ffnn() {
    let step = ffnn_step(8, 32, 16, 4).unwrap();
    let state = FfnnState::init(32, 16, 4, 3);
    let inputs = step_inputs(
        &step,
        &state,
        Tensor::random(&[8, 32], 7),
        Tensor::random(&[8, 4], 8),
    );
    assert_session_matches_driver(&step.graph, &inputs);
}

#[test]
fn session_matches_driver_bitwise_attention() {
    let (s, a, h, d) = (16, 8, 2, 4);
    let mut g = EinGraph::new();
    let q = g.input("Q", vec![s, a]);
    let k = g.input("K", vec![s, a]);
    let v = g.input("V", vec![s, a]);
    let wq = g.input("WQ", vec![a, h, d]);
    let wk = g.input("WK", vec![a, h, d]);
    let wv = g.input("WV", vec![a, h, d]);
    let wo = g.input("WO", vec![a, h, d]);
    multihead_attention(&mut g, "mha", q, k, v, wq, wk, wv, wo, false).unwrap();
    let mut inputs = HashMap::new();
    for (i, vid) in g.inputs().into_iter().enumerate() {
        inputs.insert(vid, Tensor::random(&g.vertex(vid).bound, 30 + i as u64));
    }
    assert_session_matches_driver(&g, &inputs);
}

/// The Experiment-1 chain with caller-chosen labels and build order, so
/// the cache tests can present genuinely renamed / reordered clones.
struct NamedChain {
    graph: EinGraph,
    inputs_ids: [VertexId; 5],
    z: VertexId,
}

fn build_chain(names: [&str; 4], reorder: bool, s: usize) -> NamedChain {
    let l = |n: &str| labels(n)[0];
    let (i, j, k, m) = (l(names[0]), l(names[1]), l(names[2]), l(names[3]));
    let mut g = EinGraph::new();
    let (a, b, c, d, e, z);
    if reorder {
        d = g.input("D", vec![s, s]);
        e = g.input("E", vec![s, s]);
        let de = g
            .add("DE", EinSum::contraction(vec![j, m], vec![m, k], vec![j, k]), &[d, e])
            .unwrap();
        a = g.input("A", vec![s, s]);
        b = g.input("B", vec![s, s]);
        c = g.input("C", vec![s, s]);
        let ab = g
            .add("AB", EinSum::contraction(vec![i, j], vec![j, k], vec![i, k]), &[a, b])
            .unwrap();
        let cde = g
            .add("CDE", EinSum::contraction(vec![i, j], vec![j, k], vec![i, k]), &[c, de])
            .unwrap();
        z = g
            .add(
                "Z",
                EinSum::elementwise(vec![i, k], vec![i, k], JoinOp::Add),
                &[ab, cde],
            )
            .unwrap();
    } else {
        a = g.input("A", vec![s, s]);
        b = g.input("B", vec![s, s]);
        c = g.input("C", vec![s, s]);
        d = g.input("D", vec![s, s]);
        e = g.input("E", vec![s, s]);
        let ab = g
            .add("AB", EinSum::contraction(vec![i, j], vec![j, k], vec![i, k]), &[a, b])
            .unwrap();
        let de = g
            .add("DE", EinSum::contraction(vec![j, m], vec![m, k], vec![j, k]), &[d, e])
            .unwrap();
        let cde = g
            .add("CDE", EinSum::contraction(vec![i, j], vec![j, k], vec![i, k]), &[c, de])
            .unwrap();
        z = g
            .add(
                "Z",
                EinSum::elementwise(vec![i, k], vec![i, k], JoinOp::Add),
                &[ab, cde],
            )
            .unwrap();
    }
    NamedChain {
        graph: g,
        inputs_ids: [a, b, c, d, e],
        z,
    }
}

fn random_inputs(c: &NamedChain, seed: u64) -> HashMap<VertexId, Tensor> {
    let mut m = HashMap::new();
    for (i, &v) in c.inputs_ids.iter().enumerate() {
        m.insert(v, Tensor::random(&c.graph.vertex(v).bound, seed + i as u64));
    }
    m
}

#[test]
fn cache_hits_renamed_reordered_clone_and_remaps_correctly() {
    let g1 = build_chain(["i", "j", "k", "m"], false, 24);
    let g2 = build_chain(["w", "x", "y", "z"], true, 24);

    let session = Session::new(cfg(4)).unwrap();
    let exe1 = session.compile(&g1.graph).unwrap();
    assert_eq!(exe1.provenance(), PlanProvenance::Planned);

    // label-renamed + vertex-reordered clone: a cache hit
    let exe2 = session.compile(&g2.graph).unwrap();
    assert_eq!(exe2.provenance(), PlanProvenance::CacheHit);
    assert_eq!(exe1.signature(), exe2.signature());
    let st = session.stats();
    assert_eq!((st.compiles, st.hits, st.misses), (2, 1, 1));
    assert_eq!(st.planner_runs, 1, "the hit must not re-plan");
    assert_eq!(st.lower_runs, 1, "the hit must not re-lower");
    assert_eq!(st.entries, 1);

    // the hit's vertex remap is numerically correct: run the cached
    // artifact with g2's ids and check against g2's dense reference
    let inputs2 = random_inputs(&g2, 77);
    let (outs2, rep2) = exe2.run(&inputs2).unwrap();
    assert_eq!(rep2.provenance, PlanProvenance::CacheHit);
    assert!(rep2.plan_s > 0.0, "cache hits report the real plan_s");
    let want2 = eval_graph(&g2.graph, &inputs2).unwrap();
    assert!(outs2[&g2.z].allclose(&want2[&g2.z], 1e-4, 1e-5));

    // and it is bitwise-identical to compiling g2 in a fresh session
    let fresh = Session::new(cfg(4)).unwrap();
    let (outs_fresh, _) = fresh.compile(&g2.graph).unwrap().run(&inputs2).unwrap();
    assert_eq!(outs2, outs_fresh);
}

#[test]
fn cache_misses_on_shape_change() {
    let g1 = build_chain(["i", "j", "k", "m"], false, 16);
    let g2 = build_chain(["i", "j", "k", "m"], false, 32);
    let session = Session::new(cfg(4)).unwrap();
    session.compile(&g1.graph).unwrap();
    let exe2 = session.compile(&g2.graph).unwrap();
    assert_eq!(exe2.provenance(), PlanProvenance::Planned);
    let st = session.stats();
    assert_eq!((st.hits, st.misses, st.entries), (0, 2, 2));
}

#[test]
fn run_many_is_bitwise_stable_with_zero_replanning() {
    let chain = chain_graph(32, false).unwrap();
    let inputs = chain_inputs(&chain, 13);
    let session = Session::new(cfg(4)).unwrap();
    let exe = session.compile(&chain.graph).unwrap();

    let (first, rep) = exe.run(&inputs).unwrap();
    assert_eq!(rep.provenance, PlanProvenance::Planned);
    for _ in 0..2 {
        let (outs, _) = exe.run(&inputs).unwrap();
        assert_eq!(outs, first, "repeated runs must be bitwise-identical");
    }
    // zero planner / zero lowering work after compile
    let st = session.stats();
    assert_eq!(st.planner_runs, 1);
    assert_eq!(st.lower_runs, 1);

    // recompiling the same graph is a cache hit, with cache_hit provenance
    // on its reports and still bitwise-identical outputs
    let exe2 = session.compile(&chain.graph).unwrap();
    assert_eq!(exe2.provenance(), PlanProvenance::CacheHit);
    let (outs, rep2) = exe2.run(&inputs).unwrap();
    assert_eq!(rep2.provenance, PlanProvenance::CacheHit);
    assert_eq!(outs, first);
    assert_eq!(session.stats().planner_runs, 1);
}

#[test]
fn lazy_frontend_end_to_end_matches_dense_reference() {
    let session = Session::new(cfg(2)).unwrap();
    let a = session.input("A", &[24, 24]);
    let b = session.input("B", &[24, 24]);
    let c = session.input("C", &[24, 24]);
    let ab = a.einsum("ij,jk->ik", &b).unwrap();
    let z = ab.einsum("ik,km->im", &c).unwrap().ew(JoinOp::Add, &ab).unwrap();
    let exe = session.compile_expr(&z).unwrap();

    let mut inputs = HashMap::new();
    for (i, e) in [&a, &b, &c].into_iter().enumerate() {
        inputs.insert(e.id(), Tensor::random(&[24, 24], 50 + i as u64));
    }
    let (outs, _) = exe.run(&inputs).unwrap();
    let want = eval_graph(exe.graph(), &inputs).unwrap();
    assert_eq!(outs[&z.id()], want[&z.id()]);
}

#[test]
fn extraneous_input_ids_ignored_identically_on_both_paths() {
    let g1 = build_chain(["i", "j", "k", "m"], false, 16);
    let g2 = build_chain(["p", "q", "r", "s"], true, 16);
    let session = Session::new(cfg(2)).unwrap();
    let exe1 = session.compile(&g1.graph).unwrap();
    let exe2 = session.compile(&g2.graph).unwrap();
    assert_eq!(exe2.provenance(), PlanProvenance::CacheHit);
    // extraneous ids must be ignored — on the identity path and on the
    // cache-hit remap path alike (no panic, no error, same outputs)
    let mut inputs1 = random_inputs(&g1, 9);
    let (clean1, _) = exe1.run(&inputs1).unwrap();
    inputs1.insert(VertexId(999), Tensor::random(&[16, 16], 1));
    let (extra1, _) = exe1.run(&inputs1).unwrap();
    assert_eq!(clean1, extra1);
    let mut inputs2 = random_inputs(&g2, 9);
    let (clean2, _) = exe2.run(&inputs2).unwrap();
    inputs2.insert(VertexId(999), Tensor::random(&[16, 16], 2));
    let (extra2, _) = exe2.run(&inputs2).unwrap();
    assert_eq!(clean2, extra2);
    // a *missing* required input still errors on both paths
    let mut short = random_inputs(&g2, 9);
    short.remove(&g2.inputs_ids[0]);
    assert!(exe2.run(&short).is_err());
}

#[test]
fn label_sensitive_strategies_do_not_share_cache_across_renamings() {
    // DataParallel plans by label *name* (roles: 'b' = batch), so a
    // renamed twin must MISS even though its bare canonical signature
    // matches — while an exact twin (same names, reordered build) hits.
    let build = |batch: &str, reorder: bool| {
        let l = |n: &str| labels(n)[0];
        let (b, f, h) = (l(batch), l("f"), l("h"));
        let mut g = EinGraph::new();
        let (x, w);
        if reorder {
            w = g.input("W", vec![32, 16]);
            x = g.input("X", vec![8, 32]);
        } else {
            x = g.input("X", vec![8, 32]);
            w = g.input("W", vec![32, 16]);
        }
        g.add("Y", EinSum::contraction(vec![b, f], vec![f, h], vec![b, h]), &[x, w])
            .unwrap();
        g
    };
    let session = Session::new(DriverConfig {
        workers: 4,
        p: 4,
        strategy: eindecomp::decomp::baselines::Strategy::DataParallel,
        backend: Backend::Native,
        network: NetworkProfile::loopback(),
        ..Default::default()
    })
    .unwrap();
    session.compile(&build("b", false)).unwrap();
    // renamed batch label: canonically identical, but must not hit
    let exe_renamed = session.compile(&build("q", false)).unwrap();
    assert_eq!(exe_renamed.provenance(), PlanProvenance::Planned);
    // exact twin, vertex-reordered: hits
    let exe_twin = session.compile(&build("b", true)).unwrap();
    assert_eq!(exe_twin.provenance(), PlanProvenance::CacheHit);
    assert_eq!(session.stats().entries, 2);
}

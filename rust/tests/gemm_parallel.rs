//! Differential and determinism tests for the intra-op sharded GEMM and
//! the scoped kernel paths, mirroring `scheduler_differential.rs` one
//! level down.
//!
//! Invariants locked in:
//!
//! 1. **Differential**: for random `(m, k, n, alpha, beta)` cases, the
//!    sharded kernel (`sgemm_scoped` under a 1/2/8-thread intra-op pool)
//!    is **bitwise-identical** to the serial `sgemm` — shard boundaries
//!    are a pure function of `(m, shard count)` and each C row sees the
//!    same update sequence regardless of the split;
//! 2. **Determinism**: repeated sharded runs produce identical bytes no
//!    matter how helper threads interleave;
//! 3. the same holds end-to-end through `Cluster::execute` for every
//!    `intra_op` fan-out, including the scoped einsum paths (BMM batch
//!    sharding, generic nest, unary reduction, aggregation folds).

use eindecomp::einsum::expr::{AggOp, EinSum, JoinOp};
use eindecomp::einsum::label::labels;
use eindecomp::runtime::gemm::{row_shards, sgemm, sgemm_scoped, MR};
use eindecomp::runtime::native::{eval_einsum, eval_einsum_scoped};
use eindecomp::runtime::NativeEngine;
use eindecomp::sim::{Cluster, NetworkProfile};
use eindecomp::tensor::Tensor;
use eindecomp::util::{with_intra_op_pool, Rng};
use std::collections::HashMap;

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::seed_from_u64(seed);
    (0..n).map(|_| r.next_centered()).collect()
}

/// Random (m, k, n, alpha, beta) drawn to cover MR-aligned and ragged
/// shapes, panel edges, and the alpha/beta special cases.
fn random_case(rng: &mut Rng) -> (usize, usize, usize, f32, f32) {
    let m = 1 + rng.next_below(97);
    let k = 1 + rng.next_below(300);
    let n = 1 + rng.next_below(290);
    let alpha = [1.0f32, 0.5, -2.0, 0.0][rng.next_below(4)];
    let beta = [0.0f32, 1.0, 0.5][rng.next_below(3)];
    (m, k, n, alpha, beta)
}

#[test]
fn sharded_gemm_is_bitwise_identical_to_serial() {
    let mut rng = Rng::seed_from_u64(0xD1FF);
    for case in 0..12 {
        let (m, k, n, alpha, beta) = random_case(&mut rng);
        let a = rand_vec(m * k, 1000 + case);
        let b = rand_vec(k * n, 2000 + case);
        let c0 = rand_vec(m * n, 3000 + case);
        let mut want = c0.clone();
        sgemm(m, k, n, alpha, &a, &b, beta, &mut want);
        for threads in [1usize, 2, 8] {
            let mut got = c0.clone();
            with_intra_op_pool(threads, |scope| {
                sgemm_scoped(m, k, n, alpha, &a, &b, beta, &mut got, scope);
            });
            // Tensor-free bitwise check: f32 == on every element, plus
            // bit patterns to catch -0.0 vs 0.0 drift.
            assert_eq!(got.len(), want.len());
            for (i, (x, y)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "case {case} ({m},{k},{n},{alpha},{beta}) threads {threads} elem {i}"
                );
            }
        }
    }
}

#[test]
fn sharded_gemm_deterministic_across_runs() {
    let (m, k, n) = (91, 257, 130); // straddles KB/NB panel edges
    let a = rand_vec(m * k, 7);
    let b = rand_vec(k * n, 8);
    let first = {
        let mut c = vec![0.0f32; m * n];
        with_intra_op_pool(8, |scope| {
            sgemm_scoped(m, k, n, 1.0, &a, &b, 0.0, &mut c, scope);
        });
        c
    };
    for run in 1..6 {
        let mut c = vec![0.0f32; m * n];
        with_intra_op_pool(8, |scope| {
            sgemm_scoped(m, k, n, 1.0, &a, &b, 0.0, &mut c, scope);
        });
        assert_eq!(c, first, "run {run}");
    }
}

#[test]
fn shard_plan_is_deterministic_and_aligned() {
    for m in [1usize, 4, 37, 96, 1000] {
        for s in [1usize, 2, 8, 16] {
            let plan = row_shards(m, s);
            assert_eq!(plan, row_shards(m, s), "m={m} s={s} not deterministic");
            let mut next = 0;
            for &(lo, hi) in &plan {
                assert_eq!(lo % MR, 0, "m={m} s={s}");
                assert_eq!(lo, next);
                next = hi;
            }
            assert_eq!(next, m);
        }
    }
}

#[test]
fn scoped_einsum_paths_match_serial_bitwise() {
    // Exercises each sharded path in runtime::native against its serial
    // twin: BMM (batch >= p and batch < p), the generic loop nest, and
    // the unary reduction.
    let cases: Vec<(EinSum, Vec<Vec<usize>>)> = vec![
        // plain matmul -> row-sharded GEMM
        (
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![vec![96, 80], vec![80, 72]],
        ),
        // wide batch -> batch-sharded BMM
        (
            EinSum::contraction(labels("b i j"), labels("b j k"), labels("b i k")),
            vec![vec![16, 12, 10], vec![16, 10, 8]],
        ),
        // squared-diff join -> generic nest (leading label is in l_Z)
        (
            EinSum::Binary {
                lx: labels("i j"),
                ly: labels("j k"),
                lz: labels("i k"),
                join: JoinOp::SquaredDiff,
                agg: AggOp::Sum,
            },
            vec![vec![64, 32], vec![32, 48]],
        ),
    ];
    for (ci, (op, shapes)) in cases.iter().enumerate() {
        let ts: Vec<Tensor> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::random(s, (ci * 10 + i) as u64))
            .collect();
        let refs: Vec<&Tensor> = ts.iter().collect();
        let want = eval_einsum(op, &refs).unwrap();
        for threads in [2usize, 8] {
            let got = with_intra_op_pool(threads, |scope| {
                eval_einsum_scoped(op, &refs, scope).unwrap()
            });
            assert_eq!(got, want, "case {ci} threads {threads}");
        }
    }
    // unary reduction: row-max over a tall matrix (leading label kept)
    let x = Tensor::random(&[128, 64], 42);
    let op = EinSum::reduce(labels("i j"), labels("i"), AggOp::Max);
    let want = eval_einsum(&op, &[&x]).unwrap();
    for threads in [2usize, 8] {
        let got = with_intra_op_pool(threads, |scope| {
            eval_einsum_scoped(&op, &[&x], scope).unwrap()
        });
        assert_eq!(got, want, "reduce threads {threads}");
    }
}

#[test]
fn cluster_execution_bitwise_across_intra_op_degrees() {
    // End-to-end: a two-vertex chain with forced aggregation tasks, run
    // at several intra-op fan-outs, must produce identical bytes — this
    // is the determinism story the work-stealing + intra-op design rests
    // on (mirrors scheduler_differential.rs one level down).
    let mut g = eindecomp::einsum::graph::EinGraph::new();
    let a = g.input("A", vec![64, 64]);
    let b = g.input("B", vec![64, 64]);
    let c = g.input("C", vec![64, 64]);
    let z1 = g
        .add(
            "Z1",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    let z2 = g
        .add(
            "Z2",
            EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
            vec![z1, c],
        )
        .unwrap();
    let mut plan = eindecomp::decomp::Plan::default();
    plan.parts.insert(z1, vec![2, 2, 2]); // dj = 2 forces agg tasks
    plan.parts.insert(z2, vec![2, 2, 2]);
    plan.finalize_inputs(&g);
    let mut inputs = HashMap::new();
    inputs.insert(a, Tensor::random(&[64, 64], 1));
    inputs.insert(b, Tensor::random(&[64, 64], 2));
    inputs.insert(c, Tensor::random(&[64, 64], 3));
    let engine = NativeEngine::new();
    let base = Cluster::new(4, NetworkProfile::loopback())
        .with_intra_op(1)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0;
    for intra in [0usize, 2, 4, 8] {
        for run in 0..3 {
            let got = Cluster::new(4, NetworkProfile::loopback())
                .with_intra_op(intra)
                .execute(&g, &plan, &engine, &inputs)
                .unwrap()
                .0;
            assert_eq!(got[&z2], base[&z2], "intra {intra} run {run}");
        }
    }
}

//! Differential and determinism tests for the real-execution schedulers.
//!
//! These lock in the two invariants the work-stealing rewrite must
//! preserve (see `sim/cluster.rs` module docs):
//!
//! 1. **Differential**: for randomized graphs, partitioning vectors, and
//!    worker counts, `Cluster::execute` equals single-threaded dense
//!    evaluation (`runtime::native::eval_einsum` over the topo order) in
//!    BOTH execution modes;
//! 2. **Determinism**: repeated runs of the same plan produce *bitwise*
//!    identical tensors regardless of thread interleaving, and the two
//!    modes agree bitwise with each other — aggregation combines in fixed
//!    dep order, never completion order.

use eindecomp::decomp::Plan;
use eindecomp::einsum::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use eindecomp::einsum::graph::{EinGraph, VertexId};
use eindecomp::einsum::label::Label;
use eindecomp::runtime::native::eval_einsum;
use eindecomp::runtime::NativeEngine;
use eindecomp::sim::{Cluster, ExecMode, NetworkProfile};
use eindecomp::tensor::Tensor;
use eindecomp::util::Rng;
use std::collections::HashMap;

/// Dense single-threaded reference: evaluate every vertex in topo order.
fn dense_eval(g: &EinGraph, inputs: &HashMap<VertexId, Tensor>) -> HashMap<VertexId, Tensor> {
    let mut vals: HashMap<VertexId, Tensor> = inputs.clone();
    for v in g.topo_order() {
        let vert = g.vertex(v);
        if matches!(vert.op, EinSum::Input) {
            continue;
        }
        let ins: Vec<Tensor> = vert.inputs.iter().map(|i| vals[i].clone()).collect();
        let refs: Vec<&Tensor> = ins.iter().collect();
        let t = eval_einsum(&vert.op, &refs).unwrap();
        vals.insert(v, t);
    }
    vals
}

/// Random diamond-ish DAG over 2-D tensors plus a random per-vertex plan.
/// Returns (graph, plan, inputs, output vertices). The graph mixes
/// contractions (agg tasks), elementwise joins, and unary maps; random
/// mismatched partitionings force repartition tasks.
fn random_case(seed: u64) -> (EinGraph, Plan, HashMap<VertexId, Tensor>, Vec<VertexId>) {
    let mut rng = Rng::seed_from_u64(seed);
    let s = 4 + rng.next_below(9); // 4..12
    let (i, j, k, m) = (
        Label::new("i"),
        Label::new("j"),
        Label::new("k"),
        Label::new("m"),
    );
    let mut g = EinGraph::new();
    let a = g.input("A", vec![s, s]);
    let b = g.input("B", vec![s, s]);
    let c = g.input("C", vec![s, s]);
    let z1 = g
        .add(
            "Z1",
            EinSum::contraction(vec![i, j], vec![j, k], vec![i, k]),
            vec![a, b],
        )
        .unwrap();
    let z2 = g
        .add(
            "Z2",
            EinSum::contraction(vec![i, k], vec![k, m], vec![i, m]),
            vec![z1, c],
        )
        .unwrap();
    // Z1 consumed twice (diamond) — its tiles feed Z2 and Z3 under
    // different required partitionings.
    let z3 = g
        .add(
            "Z3",
            EinSum::elementwise(vec![i, k], vec![i, k], JoinOp::Add),
            vec![z1, z2],
        )
        .unwrap();
    let z4 = g
        .add("Z4", EinSum::map(vec![i, k], UnaryOp::Relu), vec![z3])
        .unwrap();
    // reduce with Max exercises non-Sum aggregation across tiles
    let z5 = g
        .add("Z5", EinSum::reduce(vec![i, k], vec![i], AggOp::Max), vec![z4])
        .unwrap();

    let mut plan = Plan::default();
    let mut rand_d = |nlabels: usize| -> Vec<usize> {
        (0..nlabels)
            .map(|_| 1 + rng.next_below(s.min(4)))
            .collect()
    };
    plan.parts.insert(z1, rand_d(3)); // unique labels [i, j, k]
    plan.parts.insert(z2, rand_d(3)); // [i, k, m]
    plan.parts.insert(z3, rand_d(2)); // [i, k]
    plan.parts.insert(z4, rand_d(2)); // [i, k]
    plan.parts.insert(z5, rand_d(2)); // [i, k]
    plan.finalize_inputs(&g);

    let mut inputs = HashMap::new();
    inputs.insert(a, Tensor::random(&[s, s], seed * 7 + 1));
    inputs.insert(b, Tensor::random(&[s, s], seed * 7 + 2));
    inputs.insert(c, Tensor::random(&[s, s], seed * 7 + 3));
    let outs = g.outputs();
    (g, plan, inputs, outs)
}

#[test]
fn differential_random_graphs_both_modes() {
    let engine = NativeEngine::new();
    for seed in 0..30u64 {
        let (g, plan, inputs, outs) = random_case(seed);
        let want = dense_eval(&g, &inputs);
        let mut rng = Rng::seed_from_u64(seed ^ 0xABCD);
        let workers = 1 + rng.next_below(6);
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            let cluster =
                Cluster::new(workers, NetworkProfile::loopback()).with_exec_mode(mode);
            let (got, rep) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
            for &o in &outs {
                assert!(
                    got[&o].allclose(&want[&o], 1e-3, 1e-4),
                    "seed {seed} workers {workers} {mode:?}: output {o} diverged, \
                     max diff {}",
                    got[&o].max_abs_diff(&want[&o]).unwrap()
                );
            }
            assert_eq!(rep.tasks, cluster.lower(&g, &plan).unwrap().len());
        }
    }
}

#[test]
fn work_stealing_is_bitwise_deterministic() {
    let engine = NativeEngine::new();
    for seed in [3u64, 11, 19] {
        let (g, plan, inputs, outs) = random_case(seed);
        let cluster = Cluster::new(4, NetworkProfile::loopback())
            .with_exec_mode(ExecMode::WorkStealing);
        let (first, _) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
        for run in 1..6 {
            let (again, _) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
            for &o in &outs {
                // Tensor PartialEq is element-exact: bitwise determinism
                assert_eq!(first[&o], again[&o], "seed {seed} run {run} output {o}");
            }
        }
    }
}

#[test]
fn level_barrier_is_bitwise_deterministic() {
    let engine = NativeEngine::new();
    let (g, plan, inputs, outs) = random_case(5);
    let cluster =
        Cluster::new(4, NetworkProfile::loopback()).with_exec_mode(ExecMode::LevelBarrier);
    let (first, _) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
    for _ in 0..4 {
        let (again, _) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
        for &o in &outs {
            assert_eq!(first[&o], again[&o]);
        }
    }
}

#[test]
fn modes_agree_bitwise_across_worker_counts() {
    let engine = NativeEngine::new();
    for seed in [2u64, 13] {
        let (g, plan, inputs, outs) = random_case(seed);
        for workers in [1usize, 2, 5, 8] {
            let ws = Cluster::new(workers, NetworkProfile::loopback())
                .with_exec_mode(ExecMode::WorkStealing)
                .execute(&g, &plan, &engine, &inputs)
                .unwrap()
                .0;
            let lb = Cluster::new(workers, NetworkProfile::loopback())
                .with_exec_mode(ExecMode::LevelBarrier)
                .execute(&g, &plan, &engine, &inputs)
                .unwrap()
                .0;
            for &o in &outs {
                assert_eq!(ws[&o], lb[&o], "seed {seed} workers {workers} output {o}");
            }
        }
    }
}

/// Both modes report identical *modeled* accounting for the same plan —
/// the scheduler choice must not perturb ExecReport's sim/bytes ledger.
#[test]
fn modeled_accounting_independent_of_exec_mode() {
    let engine = NativeEngine::new();
    let (g, plan, inputs, _) = random_case(21);
    let base = Cluster::new(4, NetworkProfile::loopback());
    let (_, ws) = base
        .clone()
        .with_exec_mode(ExecMode::WorkStealing)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap();
    let (_, lb) = base
        .with_exec_mode(ExecMode::LevelBarrier)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap();
    assert_eq!(ws.bytes_moved, lb.bytes_moved);
    assert_eq!(ws.bytes_join, lb.bytes_join);
    assert_eq!(ws.bytes_agg, lb.bytes_agg);
    assert_eq!(ws.bytes_repart, lb.bytes_repart);
    assert_eq!(ws.kernel_calls, lb.kernel_calls);
    assert_eq!(ws.tasks, lb.tasks);
    assert!((ws.sim_makespan_s - lb.sim_makespan_s).abs() < 1e-12);
    assert!(ws.wall_s > 0.0 && lb.wall_s > 0.0);
}

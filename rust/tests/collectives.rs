//! Differential suite for the `lower-collectives` pass.
//!
//! The pass replaces O(p^2) point-to-point repartition patterns with
//! `AllGather` / `ReduceScatter` / `AllReduce` collectives, scheduled as
//! ring relays or explicit trees per the worker topology. The lowering
//! contract is *bitwise* equivalence: `AllGather` relays are pure
//! copies, and the default `Ring` reduce fold combines members in
//! exactly the baseline serial-fold order. This suite locks that in:
//!
//! * every bench workload (matrix chain, FFNN training step, one-layer
//!   attention), for p in {2, 4, 8}, under flat / two-level /
//!   three-level topologies, in BOTH real-execution modes, produces
//!   bitwise-identical outputs with the collective lowering on vs off;
//! * the sweep is not vacuous — the pass is asserted to rewrite at
//!   least one pattern per workload family;
//! * tree-scheduled reductions for float `Sum` stay out of the default
//!   pass set and out of `with_topology` (they re-associate the fold,
//!   same caveat as `agg-tree`) — `Tree` reduce is reachable only
//!   through the explicit [`PassManager::with_reduce_schedule`] opt-in.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::decomp::Plan;
use eindecomp::einsum::expr::EinSum;
use eindecomp::einsum::graph::{EinGraph, VertexId};
use eindecomp::einsum::label::labels;
use eindecomp::models::ffnn::ffnn_step;
use eindecomp::models::llama::{llama_graph, LlamaConfig};
use eindecomp::models::matchain::chain_graph;
use eindecomp::runtime::NativeEngine;
use eindecomp::sim::{Cluster, ExecMode, NetworkProfile, Topology};
use eindecomp::taskgraph::placement::{place, Policy};
use eindecomp::tensor::Tensor;
use eindecomp::tra::passes::{PassKind, PassManager, PassSelector};
use eindecomp::tra::program::{from_plan, CollectiveSchedule};
use std::collections::HashMap;

/// `lower-collectives` plus the structure-neutral cleanups it composes
/// with — the treatment arm of the differential.
fn collective_passes() -> PassSelector {
    "elide-identity-repart,lower-collectives,dead-rel-elim"
        .parse()
        .unwrap()
}

/// Re-shard every pre-partitioned input along the reversed axis order
/// (storage layout vs compute layout) so real repartition patterns
/// exist for the pass to collapse — same setup as `benches/lowering.rs`.
fn storage_shard_inputs(plan: &mut Plan) {
    for part in plan.input_parts.values_mut() {
        part.reverse();
    }
}

fn random_inputs(g: &EinGraph, seed: u64) -> HashMap<VertexId, Tensor> {
    g.inputs()
        .into_iter()
        .enumerate()
        .map(|(i, v)| (v, Tensor::random(&g.vertex(v).bound, seed + i as u64)))
        .collect()
}

/// The full differential sweep for one workload: p x topology x
/// exec-mode, collective lowering on vs off, outputs compared bitwise.
/// Returns the total number of `lower-collectives` rewrites observed so
/// callers can assert the sweep actually exercised the pass.
fn sweep(name: &str, g: &EinGraph) -> usize {
    let engine = NativeEngine::new();
    let roles = LabelRoles::by_convention();
    let net = NetworkProfile::cpu_cluster();
    let mut fired = 0usize;
    for p in [2usize, 4, 8] {
        let mut plan = assign(g, &Strategy::EinDecomp, p, &roles).unwrap();
        storage_shard_inputs(&mut plan);
        let inputs = random_inputs(g, 900 + p as u64);
        let topologies = [
            Topology::flat_of(&net, p),
            Topology::two_level_of(&net, p),
            Topology::three_level_of(&net, p),
        ];
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            // control arm: the seed-identical Safe pipeline
            let base = Cluster::new(p, NetworkProfile::cpu_cluster())
                .with_passes(PassSelector::Safe)
                .with_exec_mode(mode)
                .execute(g, &plan, &engine, &inputs)
                .unwrap()
                .0;
            for topo in &topologies {
                let cluster = Cluster::new(p, NetworkProfile::cpu_cluster())
                    .with_passes(collective_passes())
                    .with_topology(topo.clone())
                    .with_exec_mode(mode);
                let (_, _, log) = cluster.lower_explain(g, &plan).unwrap();
                fired += log
                    .entries
                    .iter()
                    .filter(|e| e.pass == "lower-collectives")
                    .map(|e| e.changes)
                    .sum::<usize>();
                let got = cluster.execute(g, &plan, &engine, &inputs).unwrap().0;
                for out in g.outputs() {
                    assert_eq!(
                        base[&out],
                        got[&out],
                        "{name} p={p} {mode:?} {}: collective lowering \
                         diverged bitwise from the safe pipeline",
                        topo.name()
                    );
                }
            }
        }
    }
    fired
}

#[test]
fn matchain_collectives_bitwise_all_topologies() {
    let chain = chain_graph(24, false).unwrap();
    let fired = sweep("matchain", &chain.graph);
    assert!(fired > 0, "sweep never triggered lower-collectives (vacuous)");
}

#[test]
fn ffnn_collectives_bitwise_all_topologies() {
    let ffnn = ffnn_step(32, 48, 24, 8).unwrap();
    let fired = sweep("ffnn", &ffnn.graph);
    assert!(fired > 0, "sweep never triggered lower-collectives (vacuous)");
}

#[test]
fn attention_collectives_bitwise_all_topologies() {
    let cfg = LlamaConfig {
        layers: 1,
        batch: 2,
        seq: 16,
        model_dim: 32,
        heads: 2,
        head_dim: 16,
        ffn_dim: 64,
    };
    let attn = llama_graph(&cfg).unwrap();
    let fired = sweep("attention", &attn.graph);
    assert!(fired > 0, "sweep never triggered lower-collectives (vacuous)");
}

/// One contraction with an 8-way aggregation group — the canonical
/// reduce-scatter shape (agg-tree is deliberately absent from the pass
/// set so the serial fold survives for `lower-collectives` to claim).
fn allreduce_case() -> (EinGraph, Plan, HashMap<VertexId, Tensor>, VertexId) {
    let mut g = EinGraph::new();
    let a = g.input("A", vec![32, 64]);
    let b = g.input("B", vec![64, 32]);
    let z = g
        .add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    let mut plan = Plan::default();
    plan.parts.insert(z, vec![1, 8, 2]); // 8-way reduce groups
    plan.finalize_inputs(&g);
    let mut inputs = HashMap::new();
    inputs.insert(a, Tensor::random(&[32, 64], 41));
    inputs.insert(b, Tensor::random(&[64, 32], 42));
    (g, plan, inputs, z)
}

/// Run `allreduce_case` through an explicit manager + manual place, so
/// the reduce schedule can be overridden (the `Cluster` builder only
/// exposes selectors — schedule overrides are a deliberate extra step).
fn run_with_reduce_schedule(schedule: CollectiveSchedule) -> Tensor {
    let (g, plan, inputs, z) = allreduce_case();
    let mut prog = from_plan(&g, &plan).unwrap();
    PassManager::new(&collective_passes())
        .with_reduce_schedule(schedule)
        .run(&mut prog);
    let mut tg = prog.emit_tasks().unwrap();
    place(&mut tg, 4, Policy::LocalityGreedy);
    tg.validate(4).unwrap();
    let cluster = Cluster::new(4, NetworkProfile::cpu_cluster());
    let engine = NativeEngine::new();
    let (outs, _) = cluster
        .run_lowered(&g, &plan, &tg, &engine, &inputs)
        .unwrap();
    outs[&z].clone()
}

/// Why tree reductions stay opt-in (mirroring the `agg-tree` precedent):
/// a tree fold re-associates floating-point `Sum` — `(a+b)+(c+d)` is not
/// bitwise `((a+b)+c)+d` — so any schedule that does not pin the
/// baseline member order cannot promise bitwise reproducibility. The
/// default `Ring` reduce IS the pinned serial fold; `Tree` reduce is
/// reachable only through `PassManager::with_reduce_schedule`, and
/// `with_topology` (which freely flips the *gather* schedule, a pure
/// copy either way) never touches it.
#[test]
fn tree_reduce_for_float_sum_is_opt_in() {
    // 1. Default managers pin the reduce fold to Ring, for every
    //    selector — including All, where lower-collectives runs.
    for sel in [PassSelector::All, PassSelector::Safe, collective_passes()] {
        assert_eq!(
            PassManager::new(&sel).reduce_schedule,
            CollectiveSchedule::Ring
        );
    }

    // 2. Topology steering picks the gather schedule only; the reduce
    //    schedule survives untouched on flat AND hierarchical trees.
    let net = NetworkProfile::cpu_cluster();
    for topo in [
        Topology::flat_of(&net, 8),
        Topology::two_level_of(&net, 8),
        Topology::three_level_of(&net, 8),
    ] {
        let mgr = PassManager::new(&PassSelector::All).with_topology(&topo);
        assert_eq!(
            mgr.reduce_schedule,
            CollectiveSchedule::Ring,
            "{}: with_topology must never select a re-associating reduce",
            topo.name()
        );
    }

    // 3. The default (Safe) pipeline does not run lower-collectives at
    //    all, so seed lowering stays byte-for-byte untouched.
    assert!(!PassKind::SAFE.contains(&PassKind::LowerCollectives));

    // 4. The contract in action: Ring reduce is bitwise-identical to
    //    the no-pass baseline; Tree reduce is numerically sound but
    //    only promises allclose — exactly why it is never implicit.
    let (g, plan, inputs, z) = allreduce_case();
    let engine = NativeEngine::new();
    let baseline = Cluster::new(4, NetworkProfile::cpu_cluster())
        .with_passes(PassSelector::None)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0[&z]
        .clone();
    let ring = run_with_reduce_schedule(CollectiveSchedule::Ring);
    assert_eq!(ring, baseline, "Ring reduce must equal the serial fold bitwise");
    let tree = run_with_reduce_schedule(CollectiveSchedule::Tree { arity: 2 });
    assert!(
        tree.allclose(&baseline, 1e-4, 1e-5),
        "Tree reduce diverged beyond float re-association tolerance: {}",
        tree.max_abs_diff(&baseline).unwrap()
    );
}

//! End-to-end integration: the public Driver API across all models and
//! strategies, numerics checked against dense references; short training
//! runs; CLI smoke.

use eindecomp::coordinator::driver::{Driver, DriverConfig};
use eindecomp::data::classifier_batch;
use eindecomp::decomp::baselines::Strategy;
use eindecomp::models::ffnn::{ffnn_step, step_inputs, FfnnState};
use eindecomp::models::llama::{llama_graph, llama_inputs, LlamaConfig};
use eindecomp::models::matchain::{chain_graph, chain_inputs, chain_reference};
use eindecomp::runtime::Backend;
use eindecomp::sim::NetworkProfile;

fn driver(strategy: Strategy, workers: usize) -> Driver {
    Driver::new(DriverConfig {
        workers,
        p: workers,
        strategy,
        backend: Backend::Native,
        network: NetworkProfile::loopback(),
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn every_strategy_correct_on_both_chains() {
    for skewed in [false, true] {
        let chain = chain_graph(80, skewed).unwrap();
        let inputs = chain_inputs(&chain, 21);
        let want = chain_reference(&chain, &inputs).unwrap();
        for strategy in [
            Strategy::EinDecomp,
            Strategy::EinDecompLinearized,
            Strategy::Greedy,
            Strategy::Sqrt,
            Strategy::DataParallel,
            Strategy::Sequence,
            Strategy::DaskLike { chunk: 20 },
        ] {
            let d = driver(strategy.clone(), 4);
            let (outs, rep) = d.run(&chain.graph, &inputs).unwrap();
            assert!(
                outs[&chain.z].allclose(&want, 1e-3, 1e-3),
                "{} skewed={skewed}",
                strategy.name()
            );
            assert!(rep.exec.kernel_calls > 0);
        }
    }
}

#[test]
fn every_strategy_correct_on_llama_block() {
    let cfg = LlamaConfig {
        layers: 1,
        batch: 2,
        seq: 16,
        model_dim: 32,
        heads: 2,
        head_dim: 16,
        ffn_dim: 64,
    };
    let model = llama_graph(&cfg).unwrap();
    let inputs = llama_inputs(&model, 31);
    let mut reference = None;
    for strategy in [
        Strategy::EinDecomp,
        Strategy::Megatron,
        Strategy::Sequence,
        Strategy::AttentionHead,
        Strategy::Greedy,
    ] {
        let d = driver(strategy.clone(), 4);
        let (outs, _) = d.run(&model.graph, &inputs).unwrap();
        let out = outs[&model.out].clone();
        assert!(out.data().iter().all(|v| v.is_finite()), "{}", strategy.name());
        match &reference {
            None => reference = Some(out),
            Some(r) => assert!(
                out.allclose(r, 1e-3, 1e-3),
                "{} diverged",
                strategy.name()
            ),
        }
    }
}

#[test]
fn worker_count_does_not_change_results() {
    let chain = chain_graph(60, true).unwrap();
    let inputs = chain_inputs(&chain, 77);
    let want = chain_reference(&chain, &inputs).unwrap();
    for workers in [1usize, 2, 3, 5, 8] {
        let d = driver(Strategy::EinDecomp, workers);
        let (outs, _) = d.run(&chain.graph, &inputs).unwrap();
        assert!(outs[&chain.z].allclose(&want, 1e-3, 1e-3), "workers={workers}");
    }
}

#[test]
fn training_reduces_loss_through_full_stack() {
    let step = ffnn_step(32, 48, 24, 8).unwrap();
    let d = driver(Strategy::EinDecomp, 4);
    let (plan, _) = d.plan(&step.graph).unwrap();
    let mut state = FfnnState::init(48, 24, 8, 9);
    let mut losses = Vec::new();
    for s in 0..60 {
        let (x, t) = classifier_batch(32, 48, 8, 0.4, 900 + s);
        let inputs = step_inputs(&step, &state, x, t);
        let (outs, _) = d.run_with_plan(&step.graph, &plan, &inputs).unwrap();
        losses.push(outs[&step.loss].at(&[]));
        state
            .apply(&outs[&step.dw1], &outs[&step.dw2], 0.4)
            .unwrap();
    }
    let first: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first * 0.8,
        "loss did not fall: {first:.4} -> {last:.4} ({losses:?})"
    );
}

#[test]
fn dry_run_matches_real_traffic() {
    // dry_run and execute must report identical modeled traffic
    let chain = chain_graph(64, false).unwrap();
    let d = driver(Strategy::EinDecomp, 4);
    let inputs = chain_inputs(&chain, 5);
    let dry = d.dry_run(&chain.graph).unwrap();
    let (_, real) = d.run(&chain.graph, &inputs).unwrap();
    assert_eq!(dry.exec.bytes_moved, real.exec.bytes_moved);
    assert_eq!(dry.exec.kernel_calls, real.exec.kernel_calls);
    assert!(real.exec.wall_s > 0.0 && dry.exec.wall_s == 0.0);
}

#[test]
fn cli_plan_and_run_smoke() {
    use eindecomp::coordinator::cli::main_with_args;
    for args in [
        vec!["plan", "--model", "chain", "--scale", "32", "--p", "4", "--compare"],
        vec!["run", "--model", "chain", "--scale", "32", "--workers", "2"],
        vec!["plan", "--model", "ffnn", "--batch", "16", "--features", "64", "--hidden", "32", "--classes", "8"],
        vec!["help"],
    ] {
        let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        main_with_args(&argv).unwrap();
    }
}

#[test]
fn program_file_roundtrip() {
    use eindecomp::coordinator::cli::main_with_args;
    let dir = std::env::temp_dir().join("eindecomp_test_prog");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("prog.ein");
    std::fs::write(
        &path,
        "input X [32, 32]\ninput Y [32, 32]\nZ = einsum ij,jk->ik X Y\nR = map relu Z\n",
    )
    .unwrap();
    let argv: Vec<String> = ["program", "--file", path.to_str().unwrap(), "--p", "4", "--run"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    main_with_args(&argv).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

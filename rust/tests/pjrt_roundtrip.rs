//! Integration: the python-AOT -> rust-PJRT path. Loads the artifacts
//! produced by `make artifacts`, executes them on the PJRT CPU client,
//! and checks numerics against the native engine — proving the three
//! layers (Pallas kernel -> jax graph -> rust runtime) compose with no
//! Python at run time.
//!
//! All execution tests skip gracefully when `artifacts/` is missing (run
//! `make artifacts` first) **or** when the build has no executing PJRT
//! runtime (`PjrtEngine::runtime_available()` — false in the
//! dependency-free build, which stubs the xla FFI), so `cargo test -q`
//! stays green on a bare machine. The registry-level tests at the bottom
//! run everywhere.

use eindecomp::einsum::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use eindecomp::einsum::label::labels;
use eindecomp::runtime::{Backend, DispatchEngine, KernelEngine, NativeEngine, PjrtEngine};
use eindecomp::tensor::Tensor;

/// Manifest dir without the runtime gate, for registry-only tests.
fn manifest_dir() -> Option<std::path::PathBuf> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        None
    }
}

fn artifacts_dir() -> Option<std::path::PathBuf> {
    if !PjrtEngine::runtime_available() {
        eprintln!("skipping: no executing PJRT runtime in this build");
        return None;
    }
    let dir = manifest_dir();
    if dir.is_none() {
        eprintln!("skipping: run `make artifacts` first");
    }
    dir
}

/// The registry loads and answers availability queries even without an
/// executing runtime — this is the part a bare machine can still verify.
#[test]
fn registry_loads_without_runtime() {
    let Some(dir) = manifest_dir() else {
        // no artifacts built: loading must fail cleanly, not panic
        assert!(PjrtEngine::load("definitely/not/a/dir").is_err());
        return;
    };
    let engine = PjrtEngine::load(&dir).unwrap();
    assert!(engine.num_artifacts() > 0);
}

/// Backend::Auto must produce correct results (via native fallback) with
/// or without a PJRT runtime attached.
#[test]
fn auto_backend_correct_without_runtime() {
    let engine = DispatchEngine::new(Backend::Auto, "artifacts")
        .unwrap_or_else(|_| DispatchEngine::native());
    let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
    let x = Tensor::random(&[16, 16], 100);
    let y = Tensor::random(&[16, 16], 101);
    let got = engine.eval(&op, &[&x, &y]).unwrap();
    let want = NativeEngine::new().eval(&op, &[&x, &y]).unwrap();
    assert!(got.allclose(&want, 1e-5, 1e-6));
    if !PjrtEngine::runtime_available() {
        assert!(!engine.has_pjrt(), "Auto must not attach a stub runtime");
    }
}

#[test]
fn manifest_loads_with_many_kernels() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    assert!(engine.num_artifacts() >= 40, "{}", engine.num_artifacts());
    assert!(engine.has("bmm", &[1, 64, 64, 64]));
    assert!(engine.has("softmax", &[64, 64]));
    assert!(!engine.has("bmm", &[999, 1, 1, 1]));
}

#[test]
fn bmm_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    let native = NativeEngine::new();
    let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
    let x = Tensor::random(&[64, 64], 1);
    let y = Tensor::random(&[64, 64], 2);
    let via_pjrt = engine.try_eval(&op, &[&x, &y]).unwrap().expect("artifact hit");
    let via_native = native.eval(&op, &[&x, &y]).unwrap();
    assert!(
        via_pjrt.allclose(&via_native, 1e-3, 1e-4),
        "max diff {}",
        via_pjrt.max_abs_diff(&via_native).unwrap()
    );
}

#[test]
fn bmm_artifact_with_batch_and_permutation() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    let native = NativeEngine::new();
    // batched contraction lowering to bmm b=2, with a transposed output
    let op = EinSum::contraction(labels("b i j"), labels("b j k"), labels("b k i"));
    let x = Tensor::random(&[2, 64, 64], 3);
    let y = Tensor::random(&[2, 64, 64], 4);
    let pjrt = engine.try_eval(&op, &[&x, &y]).unwrap().expect("hit b=2");
    let nat = native.eval(&op, &[&x, &y]).unwrap();
    assert!(pjrt.allclose(&nat, 1e-3, 1e-4));
}

#[test]
fn elementwise_and_map_artifacts_match() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    let native = NativeEngine::new();
    let x = Tensor::random(&[32, 32], 5); // 1024 elements
    let y = Tensor::random(&[32, 32], 6);
    for join in [JoinOp::Add, JoinOp::Mul, JoinOp::Sub] {
        let op = EinSum::elementwise(labels("i j"), labels("i j"), join);
        let p = engine.try_eval(&op, &[&x, &y]).unwrap().expect("ew hit");
        let n = native.eval(&op, &[&x, &y]).unwrap();
        assert!(p.allclose(&n, 1e-4, 1e-5), "{join:?}");
    }
    for u in [UnaryOp::Exp, UnaryOp::Relu, UnaryOp::Silu] {
        let op = EinSum::map(labels("i j"), u);
        let p = engine.try_eval(&op, &[&x]).unwrap().expect("map hit");
        let n = native.eval(&op, &[&x]).unwrap();
        assert!(p.allclose(&n, 1e-4, 1e-5), "{u:?}");
    }
}

#[test]
fn reduce_artifacts_match() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    let native = NativeEngine::new();
    let x = Tensor::random(&[64, 64], 7);
    for agg in [AggOp::Sum, AggOp::Max] {
        let op = EinSum::reduce(labels("i j"), labels("i"), agg);
        let p = engine.try_eval(&op, &[&x]).unwrap().expect("reduce hit");
        let n = native.eval(&op, &[&x]).unwrap();
        assert!(p.allclose(&n, 1e-4, 1e-5), "{agg:?}");
    }
}

#[test]
fn unmatched_shapes_fall_through() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
    // 17x17: no artifact
    let x = Tensor::random(&[17, 17], 8);
    let y = Tensor::random(&[17, 17], 9);
    assert!(engine.try_eval(&op, &[&x, &y]).unwrap().is_none());
}

#[test]
fn dispatch_engine_auto_uses_pjrt_then_falls_back() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = DispatchEngine::new(Backend::Auto, &dir).unwrap();
    assert!(engine.has_pjrt());
    let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
    // hit: 64^3
    let x = Tensor::random(&[64, 64], 10);
    let y = Tensor::random(&[64, 64], 11);
    engine.eval(&op, &[&x, &y]).unwrap();
    // miss: 17^3 -> native
    let x2 = Tensor::random(&[17, 17], 12);
    let y2 = Tensor::random(&[17, 17], 13);
    engine.eval(&op, &[&x2, &y2]).unwrap();
    let (pjrt_hits, native_hits) = engine.hit_counts();
    assert_eq!(pjrt_hits, 1);
    assert_eq!(native_hits, 1);
}

#[test]
fn named_artifact_execution_softmax() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    let x = Tensor::random(&[64, 64], 14);
    let out = engine.run("softmax", &[64, 64], &[&x]).unwrap();
    assert_eq!(out.shape(), &[64, 64]);
    // rows sum to one
    for r in 0..64 {
        let s: f32 = (0..64).map(|c| out.at(&[r, c])).sum();
        assert!((s - 1.0).abs() < 1e-4, "row {r}: {s}");
    }
}

#[test]
fn fused_ffnn_step_artifact_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).unwrap();
    if !engine.has("ffnn_step", &[32, 64, 32, 16]) {
        return;
    }
    // The ffnn_step module returns a 3-tuple; `run` unwraps 1-tuples, so
    // just check the registry sees it (full multi-output execution is the
    // L2 fusion demo, exercised via python). Loading+compiling it is the
    // smoke here:
    let x = Tensor::random(&[32, 64], 15);
    let w1 = Tensor::random(&[64, 32], 16);
    let w2 = Tensor::random(&[32, 16], 17);
    let t = Tensor::random(&[32, 16], 18);
    // compiles; execution returns tuple-3 which to_tuple1 rejects
    let res = engine.run("ffnn_step", &[32, 64, 32, 16], &[&x, &w1, &w2, &t]);
    assert!(res.is_err() || res.is_ok());
}
